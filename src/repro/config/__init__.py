from repro.config.base import (
    FLConfig,
    InputShape,
    MeshConfig,
    ModelConfig,
    TrainConfig,
    get_arch,
    list_archs,
    register_arch,
)

__all__ = [
    "ModelConfig",
    "FLConfig",
    "MeshConfig",
    "TrainConfig",
    "InputShape",
    "register_arch",
    "get_arch",
    "list_archs",
]
