from repro.config.base import (
    ModelConfig,
    FLConfig,
    MeshConfig,
    TrainConfig,
    InputShape,
    register_arch,
    get_arch,
    list_archs,
)

__all__ = [
    "ModelConfig",
    "FLConfig",
    "MeshConfig",
    "TrainConfig",
    "InputShape",
    "register_arch",
    "get_arch",
    "list_archs",
]
