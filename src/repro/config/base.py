"""Config system for the repro framework.

Every selectable architecture is a ``ModelConfig`` registered under its
``--arch`` id.  Configs are plain frozen dataclasses so they hash, print,
and round-trip through ``replace`` cleanly.  ``reduced()`` derives the
CPU-smoke-test variant of any config (<=2 layers, d_model<=512,
<=4 experts) without changing the architecture family.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "audio", "hybrid", "ssm", "vlm", "cnn")
ACTIVATIONS = ("swiglu", "squared_relu", "gelu", "relu")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (transformer backbone or CNN)."""

    arch_id: str
    family: str                      # one of FAMILIES
    num_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    activation: str = "swiglu"
    head_dim: int = 0                # 0 -> d_model // n_heads
    # positional / attention
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention
    attn_logit_softcap: float = 0.0
    causal: bool = True              # False for encoder-only (audio)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN residual beside MoE
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_dense_ff: int = 0            # width of the dense residual FFN
    # SSM / recurrent
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    # xLSTM
    slstm_every: int = 0             # every k-th block is an sLSTM block
    proj_factor: float = 2.0
    # hybrid (hymba)
    hybrid_parallel: bool = False    # attention and SSM heads in parallel
    # modality frontend stubs
    frontend: str = "none"           # "none" | "audio_frames" | "vq_patches"
    image_tokens: int = 1024         # chameleon VQ tokens per image
    # CNN (paper's own models)
    cnn_channels: Tuple[int, ...] = ()
    cnn_fc: Tuple[int, ...] = ()
    input_hw: Tuple[int, int, int] = (28, 28, 1)
    n_classes: int = 10
    resnet: bool = False
    # numerics
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    citation: str = ""

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.activation not in ACTIVATIONS:
            raise ValueError(f"unknown activation {self.activation!r}")
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError(
                f"{self.arch_id}: n_heads={self.n_heads} not divisible by "
                f"n_kv_heads={self.n_kv_heads}")

    # -- derived sizes ------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_encoder_only(self) -> bool:
        return self.family == "audio"

    @property
    def subquadratic(self) -> bool:
        """True if decode over very long contexts is O(window) or O(1)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        if self.family == "cnn":
            return _cnn_param_count(self)
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        per = 0
        if self.family != "ssm":                      # attention present
            per += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "hybrid":                   # parallel ssm heads
            per += _ssm_params(self)
        if self.family == "ssm":
            per += _xlstm_params(self)
        if self.family == "moe":
            ff3 = 3 if self.activation == "swiglu" else 2
            per += self.n_experts * ff3 * d * self.d_ff + d * self.n_experts
            if self.moe_dense_residual:
                per += ff3 * d * (self.moe_dense_ff or self.d_ff)
        elif self.d_ff:
            ff3 = 3 if self.activation == "swiglu" else 2
            per += ff3 * d * self.d_ff
        per += 2 * d                                   # two RMSNorm scales
        return n + L * per

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        ff3 = 3 if self.activation == "swiglu" else 2
        inactive = L * (self.n_experts - self.top_k) * ff3 * d * self.d_ff
        return self.param_count() - inactive

    def reduced(self) -> "ModelConfig":
        """Reduced variant for CPU smoke tests (same family/topology)."""
        if self.family == "cnn":
            return dataclasses.replace(
                self, arch_id=self.arch_id + "-reduced",
                cnn_channels=tuple(min(c, 8) for c in self.cnn_channels),
                cnn_fc=tuple(min(c, 32) for c in self.cnn_fc[:-1]) + (self.cnn_fc[-1],),
            )
        n_heads = min(self.n_heads, 4)
        ratio = max(1, self.n_heads // max(1, self.n_kv_heads))
        n_kv = max(1, n_heads // min(ratio, n_heads))
        d_model = min(self.d_model, 256)
        head_dim = d_model // n_heads
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-reduced",
            num_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            moe_dense_ff=min(self.moe_dense_ff, 256) if self.moe_dense_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            image_tokens=16,
        )


def _ssm_params(cfg: ModelConfig) -> int:
    d_in = cfg.ssm_expand * cfg.d_model
    return (cfg.d_model * 2 * d_in + d_in * cfg.ssm_conv
            + d_in * (2 * cfg.ssm_state + 1) + d_in  # x->B,C,dt ; A per chan
            + d_in * cfg.d_model)

def _xlstm_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in = int(cfg.proj_factor * d)
    # mLSTM-ish block: up/gate proj, qkv, i/f gates, out
    return 2 * d * d_in + 3 * d_in * d_in // max(1, cfg.n_heads) + 2 * d_in + d_in * d

def _cnn_param_count(cfg: ModelConfig) -> int:
    h, w, c_in = cfg.input_hw
    n = 0
    c = c_in
    for ch in cfg.cnn_channels:
        n += 3 * 3 * c * ch + ch
        c = ch
    flat = (h // (2 ** len(cfg.cnn_channels))) * (w // (2 ** len(cfg.cnn_channels))) * c
    dims = (flat,) + cfg.cnn_fc
    for a, b in zip(dims[:-1], dims[1:]):
        n += a * b + b
    return n


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# FL / training / mesh configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FLConfig:
    """FedDCT + experiment hyper-parameters (paper §5.1 defaults)."""

    n_clients: int = 50
    n_tiers: int = 5                 # M
    tau: int = 5                     # clients selected per tier
    beta: float = 1.2                # timeout tolerance
    kappa: int = 1                   # evaluation rounds
    omega: float = 30.0              # max timeout threshold (s)
    rounds: int = 200                # N
    local_epochs: int = 1
    batch_size: int = 10
    lr: float = 0.001
    optimizer: str = "adam"
    method: str = "feddct"           # feddct|fedavg|tifl|fedasync
    # wireless model
    tier_delay_means: Tuple[float, ...] = (5.0, 10.0, 15.0, 20.0, 25.0)
    delay_std: float = 2.0
    mu: float = 0.0                  # failure probability
    failure_delay: Tuple[float, float] = (30.0, 60.0)
    # data heterogeneity
    primary_frac: float = 0.7        # "#" in the paper; 0 => iid
    seed: int = 0
    # fedasync
    async_alpha: float = 0.6
    async_staleness: str = "poly"    # poly | constant
    async_a: float = 0.5
    target_accuracy: float = 0.0     # 0 = run all rounds


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


@dataclass(frozen=True)
class TrainConfig:
    dtype: str = "bfloat16"          # activations/params dtype for lowering
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = True                # shard params over the data axis too
    seed: int = 0
    # beyond-paper perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    moe_group_tokens: int = 4096
    context_parallel: str = "auto"   # "never" = paper-faithful baseline
    seq_parallel: bool = False       # megatron-style sequence parallelism
    long_ctx_swa: bool = True        # SWA override for long_500k
    decode_headdim_shard: bool = True
    parallelism: str = "tp_fsdp"     # "fsdp_only" = pure ZeRO-3 data par.
    remat_policy: str = "full"       # "dots" = save matmul outputs only


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_arch(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False

def _ensure_loaded():
    global _LOADED
    if not _LOADED:
        import repro.configs  # noqa: F401  (registers everything)
        _LOADED = True
