"""Wireless-network delay model (paper §5.1).

Clients are split into M resource groups; client c in group g has a
per-round training delay ~ N(mean_g, std).  With probability mu the round
suffers a transmission/compute failure adding U(30, 60) seconds.  All
draws are deterministic functions of (seed, client, round, attempt) so
every FL method sees the *identical* network realization — the paper's
comparisons assume this.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Vectorized PCG64 seeding
#
# ``delay`` keys an independent PCG64 stream off every (seed, client,
# round, attempt) tuple, so a cohort of C clients pays C full
# ``default_rng`` constructions — SeedSequence entropy hashing dominates
# and is a host-side hot spot in long simulations.  The hash itself
# (numpy's SeedSequence pool mix + generate_state, frozen by numpy's
# stream-compatibility guarantee) is plain uint32 arithmetic, so we run
# it across the whole cohort as numpy array ops and then seat each
# resulting (state, inc) pair into ONE reused PCG64 via its documented
# ``.state`` setter.  Bit-for-bit equality with ``default_rng(seed)`` is
# asserted by tests/test_runtime.py.
# ---------------------------------------------------------------------------

_INIT_A = 0x43b0d7e5
_MULT_A = 0x931e8875
_INIT_B = 0x8b51f9dd
_MULT_B = 0x58f38ded
_MIX_L = 0xca01f9dd
_MIX_R = 0x4973f715
_PCG_MULT = (0x2360ed051fc65da4 << 64) + 0x4385df649fccf645
_M128 = (1 << 128) - 1


def _pcg64_states(seeds: np.ndarray) -> List[Tuple[int, int]]:
    """SeedSequence(seed) -> seeded PCG64 (state, inc) for a whole batch.

    Reproduces numpy's entropy pool mix and generate_state word-for-word
    (seeds < 2**64; low/high uint32 words — a high word of 0 hashes
    identically to the 1-word entropy path), then applies PCG64's
    srandom step in 128-bit Python ints.
    """
    u32 = np.uint32
    e0 = (seeds & 0xffffffff).astype(u32)
    e1 = ((seeds >> np.uint64(32)) & 0xffffffff).astype(u32)
    hc = _INIT_A

    def _hash(val, hc, mult):
        val = val ^ u32(hc)
        hc = (hc * mult) & 0xffffffff
        val = val * u32(hc)
        val ^= val >> u32(16)
        return val, hc

    pool = [None] * 4
    pool[0], hc = _hash(e0, hc, _MULT_A)
    pool[1], hc = _hash(e1, hc, _MULT_A)
    zero = np.zeros_like(e0)
    pool[2], hc = _hash(zero, hc, _MULT_A)
    pool[3], hc = _hash(zero, hc, _MULT_A)
    for i_src in range(4):
        for i_dst in range(4):
            if i_src != i_dst:
                h, hc = _hash(pool[i_src], hc, _MULT_A)
                r = pool[i_dst] * u32(_MIX_L) - h * u32(_MIX_R)
                pool[i_dst] = r ^ (r >> u32(16))
    hc = _INIT_B
    words = []
    for i in range(8):
        d, hc = _hash(pool[i % 4], hc, _MULT_B)
        words.append(d.astype(np.uint64))
    w64 = [words[2 * k] | (words[2 * k + 1] << np.uint64(32))
           for k in range(4)]
    hi_s, lo_s, hi_i, lo_i = (w.tolist() for w in w64)
    out = []
    for k in range(len(hi_s)):
        initstate = (hi_s[k] << 64) | lo_s[k]
        inc = ((((hi_i[k] << 64) | lo_i[k]) << 1) | 1) & _M128
        st = ((inc + initstate) * _PCG_MULT + inc) & _M128
        out.append((st, inc))
    return out


class WirelessNetwork:
    def __init__(self, n_clients: int, tier_delay_means: Sequence[float],
                 delay_std: float = 2.0, mu: float = 0.0,
                 failure_delay: Tuple[float, float] = (30.0, 60.0),
                 seed: int = 0):
        self.n_clients = n_clients
        self.mu = float(mu)
        self.failure_delay = failure_delay
        self.delay_std = float(delay_std)
        self.seed = int(seed)
        g = len(tier_delay_means)
        # paper: "divide all clients into M parts" — contiguous groups
        self.group = np.repeat(np.arange(g), -(-n_clients // g))[:n_clients]
        self.means = np.asarray(tier_delay_means, np.float64)[self.group]

    def _rng(self, client: int, rnd: int, attempt: int = 0):
        return np.random.default_rng(
            (self.seed * 1_000_003 + client * 9_176 + rnd * 131 + attempt)
            % (2 ** 63))

    def delay(self, client: int, rnd: int, attempt: int = 0) -> float:
        """Sampled wall-clock cost of one local round for ``client``."""
        rng = self._rng(client, rnd, attempt)
        base = max(0.1, rng.normal(self.means[client], self.delay_std))
        if rng.random() < self.mu:
            lo, hi = self.failure_delay
            base += rng.uniform(lo, hi)
        return float(base)

    def delays(self, clients, rnd, attempt=0) -> np.ndarray:
        """Sample a whole cohort in one call, bit-for-bit identical to
        ``[delay(c, r, a) for ...]``.

        ``rnd`` and ``attempt`` may be scalars or per-client arrays
        (broadcast against ``clients``).  The per-stream SeedSequence
        entropy hash runs once for the whole cohort as vectorized
        uint32 numpy ops (see ``_pcg64_states``); each element then
        costs only a PCG64 ``.state`` seat + the draws themselves,
        instead of a full ``default_rng`` construction.  The failure
        draw is skipped when ``mu == 0`` (nothing is sampled after it,
        so skipping cannot shift any stream).
        """
        cl = np.atleast_1d(np.asarray(clients, np.int64))
        n = cl.shape[0]
        if n == 0:
            return np.empty(0, np.float64)
        rnds = np.asarray(rnd, np.int64)
        atts = np.asarray(attempt, np.int64)
        # the Python-int expression in _rng is exact (mod 2**63); int64
        # arithmetic is not.  Seeds stay in [0, 2**63) for any realistic
        # sim (seed >= 0, clients/rounds < ~1e9); fall back to the exact
        # per-call path if any element could wrap past 2**63 (hi bound)
        # or go negative (lo bound — e.g. a negative WirelessNetwork
        # seed).  A subclass that overrides the scalar sampler (test
        # scenarios) must keep its semantics, so it also takes the
        # per-call path.
        base = self.seed * 1_000_003
        hi = (base + int(cl.max()) * 9_176 + int(rnds.max()) * 131
              + int(atts.max()))
        lo = (base + int(cl.min()) * 9_176 + int(rnds.min()) * 131
              + int(atts.min()))
        if (hi >= 2 ** 63 or lo < 0
                or type(self).delay is not WirelessNetwork.delay):
            return np.asarray(
                [self.delay(int(c), int(r), int(a)) for c, r, a in
                 zip(cl, np.broadcast_to(rnds, cl.shape),
                     np.broadcast_to(atts, cl.shape))])
        seeds = (self.seed * 1_000_003 + cl * 9_176 + rnds * 131 + atts)
        states = _pcg64_states(seeds.astype(np.uint64))
        out = np.empty(n, np.float64)
        bg = np.random.PCG64(0)
        rng = np.random.Generator(bg)
        sdict = {"bit_generator": "PCG64",
                 "state": {"state": 0, "inc": 0},
                 "has_uint32": 0, "uinteger": 0}
        inner = sdict["state"]
        means = self.means.tolist()
        std, mu = self.delay_std, self.mu
        lo, hi = self.failure_delay
        check_fail = mu > 0.0
        for i, c in enumerate(cl.tolist()):
            inner["state"], inner["inc"] = states[i]
            bg.state = sdict
            base = rng.normal(means[c], std)
            if base < 0.1:
                base = 0.1
            if check_fail and rng.random() < mu:
                base += rng.uniform(lo, hi)
            out[i] = base
        return out

    def expected_mean(self, client: int) -> float:
        return float(self.means[client])
