"""Wireless-network delay model (paper §5.1).

Clients are split into M resource groups; client c in group g has a
per-round training delay ~ N(mean_g, std).  With probability mu the round
suffers a transmission/compute failure adding U(30, 60) seconds.  All
draws are deterministic functions of (seed, client, round, attempt) so
every FL method sees the *identical* network realization — the paper's
comparisons assume this.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


class WirelessNetwork:
    def __init__(self, n_clients: int, tier_delay_means: Sequence[float],
                 delay_std: float = 2.0, mu: float = 0.0,
                 failure_delay: Tuple[float, float] = (30.0, 60.0),
                 seed: int = 0):
        self.n_clients = n_clients
        self.mu = float(mu)
        self.failure_delay = failure_delay
        self.delay_std = float(delay_std)
        self.seed = int(seed)
        g = len(tier_delay_means)
        # paper: "divide all clients into M parts" — contiguous groups
        self.group = np.repeat(np.arange(g), -(-n_clients // g))[:n_clients]
        self.means = np.asarray(tier_delay_means, np.float64)[self.group]

    def _rng(self, client: int, rnd: int, attempt: int = 0):
        return np.random.default_rng(
            (self.seed * 1_000_003 + client * 9_176 + rnd * 131 + attempt)
            % (2 ** 63))

    def delay(self, client: int, rnd: int, attempt: int = 0) -> float:
        """Sampled wall-clock cost of one local round for ``client``."""
        rng = self._rng(client, rnd, attempt)
        base = max(0.1, rng.normal(self.means[client], self.delay_std))
        if rng.random() < self.mu:
            lo, hi = self.failure_delay
            base += rng.uniform(lo, hi)
        return float(base)

    def expected_mean(self, client: int) -> float:
        return float(self.means[client])
