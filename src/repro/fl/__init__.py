from repro.fl.network import WirelessNetwork
from repro.fl.client import CNNTrainer, LMTrainer, build_fl_clients
from repro.fl.metrics import RunHistory

__all__ = ["WirelessNetwork", "CNNTrainer", "LMTrainer", "build_fl_clients",
           "RunHistory"]
