from repro.fl.client import CNNTrainer, LMTrainer, build_fl_clients
from repro.fl.metrics import RunHistory
from repro.fl.network import WirelessNetwork

__all__ = ["WirelessNetwork", "CNNTrainer", "LMTrainer", "build_fl_clients",
           "RunHistory"]
