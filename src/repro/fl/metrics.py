"""Run history: accuracy / time / tier traces, JSON round-trip."""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field, fields
from typing import Dict, List, Optional

# serialization schema of ``to_json``; bump on breaking layout changes.
# v0 = the pre-versioned ``__dict__`` dump (no ``schema_version`` key),
# still accepted by ``from_json``.
SCHEMA_VERSION = 1


@dataclass
class RunHistory:
    method: str
    arch: str
    times: List[float] = field(default_factory=list)       # virtual seconds
    rounds: List[int] = field(default_factory=list)
    accuracy: List[float] = field(default_factory=list)
    tier: List[int] = field(default_factory=list)
    n_selected: List[int] = field(default_factory=list)
    n_stragglers: List[int] = field(default_factory=list)
    meta: Dict = field(default_factory=dict)

    def record(self, *, time: float, rnd: int, acc: float, tier: int = 0,
               n_selected: int = 0, n_stragglers: int = 0):
        self.times.append(float(time))
        self.rounds.append(int(rnd))
        self.accuracy.append(float(acc))
        self.tier.append(int(tier))
        self.n_selected.append(int(n_selected))
        self.n_stragglers.append(int(n_stragglers))

    def best_accuracy(self, smooth: int = 5) -> float:
        if not self.accuracy:
            return 0.0
        import numpy as np
        a = np.asarray(self.accuracy)
        if len(a) < smooth:
            return float(a.max())
        k = np.convolve(a, np.ones(smooth) / smooth, mode="valid")
        return float(k.max())

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for t, a in zip(self.times, self.accuracy):
            if a >= target:
                return t
        return None

    # -- JSON round-trip -------------------------------------------------
    def to_json(self) -> Dict:
        """Plain-dict form with an explicit top-level ``schema_version``
        (kept OUT of ``meta`` so a load/save cycle leaves ``meta``
        byte-identical to what the run recorded)."""
        d = {"schema_version": SCHEMA_VERSION}
        d.update({f.name: getattr(self, f.name) for f in fields(self)})
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "RunHistory":
        """Inverse of ``to_json``.  Accepts legacy v0 dicts (no
        ``schema_version``); rejects versions newer than this code;
        ignores unknown keys so minor forward drift loads."""
        d = dict(d)
        version = d.pop("schema_version", 0)
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"RunHistory schema_version {version} is newer than "
                f"supported {SCHEMA_VERSION}; upgrade the code")
        known = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f)

    @classmethod
    def load(cls, path: str) -> "RunHistory":
        with open(path) as f:
            return cls.from_json(json.load(f))
