"""Run history: accuracy / time / tier traces, JSON round-trip."""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class RunHistory:
    method: str
    arch: str
    times: List[float] = field(default_factory=list)       # virtual seconds
    rounds: List[int] = field(default_factory=list)
    accuracy: List[float] = field(default_factory=list)
    tier: List[int] = field(default_factory=list)
    n_selected: List[int] = field(default_factory=list)
    n_stragglers: List[int] = field(default_factory=list)
    meta: Dict = field(default_factory=dict)

    def record(self, *, time: float, rnd: int, acc: float, tier: int = 0,
               n_selected: int = 0, n_stragglers: int = 0):
        self.times.append(float(time))
        self.rounds.append(int(rnd))
        self.accuracy.append(float(acc))
        self.tier.append(int(tier))
        self.n_selected.append(int(n_selected))
        self.n_stragglers.append(int(n_stragglers))

    def best_accuracy(self, smooth: int = 5) -> float:
        if not self.accuracy:
            return 0.0
        import numpy as np
        a = np.asarray(self.accuracy)
        if len(a) < smooth:
            return float(a.max())
        k = np.convolve(a, np.ones(smooth) / smooth, mode="valid")
        return float(k.max())

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for t, a in zip(self.times, self.accuracy):
            if a >= target:
                return t
        return None

    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.__dict__, f)

    @classmethod
    def load(cls, path: str) -> "RunHistory":
        with open(path) as f:
            d = json.load(f)
        return cls(**d)
