"""FL client trainers.

A *Trainer* binds a model family to the FL loop:
    init_params(seed)                          -> params
    local_train(params, client_id, rnd_seed)   -> (new_params, n_samples)
    evaluate(params)                           -> accuracy in [0,1]

``CNNTrainer`` reproduces the paper's workloads (CNN / ResNet8, real SGD
on real batches).  ``LMTrainer`` makes any assigned LLM architecture an
FL workload (reduced config on CPU; full config under pjit on a mesh) —
its "accuracy" is next-token top-1 on a held-out batch, which drives
Eq. 3 tier movement exactly like test accuracy does for CNNs.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import FLConfig, ModelConfig
from repro.data.partition import primary_class_partition
from repro.data.pipeline import ClientDataset, client_batches
from repro.data.synthetic import make_image_dataset, make_token_dataset
from repro.models.cnn import cnn_forward, cnn_loss, init_cnn
from repro.models.transformer import forward as lm_forward
from repro.models.transformer import init_model, lm_loss
from repro.optim import make_optimizer


class CNNTrainer:
    def __init__(self, cfg: ModelConfig, fl: FLConfig, dataset: str,
                 scale: float = 0.05):
        self.cfg = cfg
        self.fl = fl
        data = make_image_dataset(dataset, seed=fl.seed, scale=scale)
        parts = primary_class_partition(
            data["y_train"], fl.n_clients, fl.primary_frac, seed=fl.seed)
        self.clients: List[ClientDataset] = [
            ClientDataset(data["x_train"][p], data["y_train"][p])
            for p in parts]
        self.x_test = jnp.asarray(data["x_test"])
        self.y_test = jnp.asarray(data["y_test"])
        self.opt = make_optimizer(fl.optimizer)
        self._step = jax.jit(self._step_impl, static_argnames=("im2col",))
        self._eval = jax.jit(self._eval_impl)
        self._batch_train = jax.jit(self._batch_train_impl)
        self._batch_train_multi = jax.jit(self._batch_train_multi_impl)

    def _step_impl(self, params, opt_state, x, y, im2col: bool = False):
        loss, grads = jax.value_and_grad(
            lambda p: cnn_loss(self.cfg, p, {"x": x, "y": y},
                               im2col=im2col))(params)
        ups, opt_state = self.opt.update(grads, opt_state, params, self.fl.lr)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, ups)
        return params, opt_state, loss

    def _eval_impl(self, params, x, y):
        logits = cnn_forward(self.cfg, params, x)
        return jnp.mean(jnp.argmax(logits, -1) == y)

    def init_params(self, seed: int = 0):
        return init_cnn(self.cfg, jax.random.PRNGKey(seed))

    def local_train(self, params, client_id: int, rnd_seed: int):
        ds = self.clients[client_id]
        opt_state = self.opt.init(params)
        for ep in range(self.fl.local_epochs):
            for x, y in client_batches(ds, self.fl.batch_size,
                                       rnd_seed * 131 + ep):
                params, opt_state, _ = self._step(
                    params, opt_state, jnp.asarray(x), jnp.asarray(y))
        return params, len(ds)

    # -- batched multi-client path (engine hot path) --------------------
    def _client_epoch_batches(self, client_id: int, rnd_seed: int):
        """All local-training batches for one client, identical stream to
        the looped ``local_train`` (same seeds, same order)."""
        ds = self.clients[client_id]
        xs, ys = [], []
        for ep in range(self.fl.local_epochs):
            for x, y in client_batches(ds, self.fl.batch_size,
                                       rnd_seed * 131 + ep):
                xs.append(x)
                ys.append(y)
        return np.stack(xs), np.stack(ys)          # (T, B, ...), (T, B)

    def _batch_train_impl(self, params, xs, ys):
        """xs (C, T, B, H, W, ch), ys (C, T, B) -> stacked params (C, ...).

        vmap over the client axis of a lax.scan over local steps: the
        whole multi-client round is ONE compiled XLA program instead of
        C * T eager dispatches.
        """
        def one_client(x_seq, y_seq):
            opt_state = self.opt.init(params)
            def step(carry, xy):
                p, o = carry
                # im2col keeps per-client conv kernels on the GEMM fast
                # path under the client-axis vmap
                p, o, loss = self._step_impl(p, o, xy[0], xy[1],
                                             im2col=True)
                return (p, o), loss
            (p, _), _ = jax.lax.scan(step, (params, opt_state),
                                     (x_seq, y_seq))
            return p
        return jax.vmap(one_client)(xs, ys)

    def _bucketed_train(self, keys, train_chunk):
        """Shared shape-bucketing for the batched paths: build each
        (client, seed)-keyed batch stream once, bucket positions by
        stream shape (ragged partitions), run ``train_chunk(xs, ys,
        positions)`` per bucket, and reassemble chunk rows in input
        order."""
        data = {}                     # pad slots repeat (client, seed)
        buckets: Dict[tuple, List[int]] = {}
        for pos, key in enumerate(keys):
            if key not in data:       # keys, so compute each stream once
                data[key] = self._client_epoch_batches(*key)
            buckets.setdefault(data[key][0].shape, []).append(pos)
        chunks, order = [], []
        for positions in buckets.values():
            xs = jnp.asarray(np.stack([data[keys[p]][0]
                                       for p in positions]))
            ys = jnp.asarray(np.stack([data[keys[p]][1]
                                       for p in positions]))
            chunks.append(train_chunk(xs, ys, positions))
            order.extend(positions)
        if len(chunks) == 1:          # common case: one shape bucket,
            return chunks[0]          # order already the input order
        inv = np.argsort(np.asarray(order))
        return jax.tree_util.tree_map(
            lambda *leaves: jnp.concatenate(leaves, axis=0)[inv], *chunks)

    def local_train_batch(self, params, client_ids, rnd_seed: int, *,
                          wrap=None):
        """Train many clients in one jitted vmapped scan.

        Clients whose local batch streams have differing shapes (ragged
        partitions) are bucketed by shape; each bucket is one compiled
        call.  Returns (stacked_params with leading axis len(client_ids)
        in input order, sizes array).

        ``wrap`` is the distributed-engine hook: it receives the pure
        train function plus the number of leading replicated args and
        returns the runner to use (the client-sharded shard_map path).
        """
        sizes = np.asarray([len(self.clients[c]) for c in client_ids],
                           np.float32)
        run = (self._batch_train if wrap is None
               else wrap(self._batch_train_impl, 1))
        stacked = self._bucketed_train(
            [(c, rnd_seed) for c in client_ids],
            lambda xs, ys, positions: run(params, xs, ys))
        return stacked, sizes

    # -- per-client start params (async runtime hot path) ---------------
    def _batch_train_multi_impl(self, start_params, xs, ys):
        """Like ``_batch_train_impl`` but every client starts from its
        OWN model snapshot: ``start_params`` carries a leading client
        axis, vmapped alongside the data."""
        def one_client(p0, x_seq, y_seq):
            opt_state = self.opt.init(p0)
            def step(carry, xy):
                p, o = carry
                p, o, loss = self._step_impl(p, o, xy[0], xy[1],
                                             im2col=True)
                return (p, o), loss
            (p, _), _ = jax.lax.scan(step, (p0, opt_state), (x_seq, y_seq))
            return p
        return jax.vmap(one_client)(start_params, xs, ys)

    def local_train_cohort(self, start_params, client_ids, rnd_seeds, *,
                           wrap=None):
        """Async-window cohort: per-client start models AND per-client
        data-stream seeds, one jitted vmapped scan.

        ``start_params`` is a stacked pytree (leading axis
        len(client_ids)) of the model snapshot each client trains from;
        batch streams are identical to looping
        ``local_train(start_i, c_i, seed_i)``.  ``wrap``: see
        ``local_train_batch`` (every arg is per-client here, so zero
        replicated args).
        """
        sizes = np.asarray([len(self.clients[c]) for c in client_ids],
                           np.float32)
        run = (self._batch_train_multi if wrap is None
               else wrap(self._batch_train_multi_impl, 0))

        def chunk(xs, ys, positions):
            idx = jnp.asarray(np.asarray(positions, np.int32))
            starts = jax.tree_util.tree_map(lambda l: l[idx], start_params)
            return run(starts, xs, ys)

        stacked = self._bucketed_train(list(zip(client_ids, rnd_seeds)),
                                       chunk)
        return stacked, sizes

    def evaluate(self, params, max_samples: int = 2048) -> float:
        n = min(max_samples, self.x_test.shape[0])
        accs = []
        for i in range(0, n, 512):
            accs.append(float(self._eval(params, self.x_test[i:i + 512],
                                         self.y_test[i:i + 512])))
        return float(np.mean(accs))


class LMTrainer:
    """FL over a (reduced or pjit-sharded) LM architecture."""

    def __init__(self, cfg: ModelConfig, fl: FLConfig, seq_len: int = 128,
                 batch: int = 8, corpus_tokens: int = 200_000,
                 step_fn=None, init_fn=None):
        self.cfg = cfg
        self.fl = fl
        self.seq = seq_len
        self.batch = batch
        toks = make_token_dataset(cfg.vocab_size, corpus_tokens, seed=fl.seed)
        splits = np.array_split(toks[:-corpus_tokens // 10], fl.n_clients)
        self.client_toks = splits
        self.test_toks = toks[-corpus_tokens // 10:]
        self.opt = make_optimizer(fl.optimizer)
        self._custom_step = step_fn is not None
        self._step = step_fn or jax.jit(self._step_impl)
        self._init_fn = init_fn
        self._eval = jax.jit(self._eval_impl)
        self._batch_train = jax.jit(self._batch_train_impl)
        self._batch_train_multi = jax.jit(self._batch_train_multi_impl)

    def _step_impl(self, params, opt_state, tokens):
        def loss_fn(p):
            l, _ = lm_loss(self.cfg, p, {"tokens": tokens})
            return l
        loss, grads = jax.value_and_grad(loss_fn)(params)
        ups, opt_state = self.opt.update(grads, opt_state, params, self.fl.lr)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                          ).astype(p.dtype), params, ups)
        return params, opt_state, loss

    def _eval_impl(self, params, tokens):
        logits, _ = lm_forward(self.cfg, params, {"tokens": tokens})
        pred = jnp.argmax(logits[:, :-1], -1)
        return jnp.mean(pred == tokens[:, 1:])

    def _batch(self, toks: np.ndarray, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        n = max(len(toks) - self.seq - 1, 1)
        starts = rng.integers(0, n, self.batch)
        return np.stack([toks[s:s + self.seq] for s in starts])

    def init_params(self, seed: int = 0):
        if self._init_fn is not None:
            return self._init_fn(seed)
        return init_model(self.cfg, jax.random.PRNGKey(seed))

    def local_train(self, params, client_id: int, rnd_seed: int):
        toks = self.client_toks[client_id]
        opt_state = self.opt.init(params)
        for ep in range(self.fl.local_epochs):
            b = jnp.asarray(self._batch(toks, rnd_seed * 131 + ep))
            params, opt_state, _ = self._step(params, opt_state, b)
        return params, len(toks)

    def _batch_train_impl(self, params, tokens):
        """tokens (C, E, B, S) -> stacked params (C, ...)."""
        def one_client(tok_seq):
            opt_state = self.opt.init(params)
            def step(carry, tok):
                p, o = carry
                p, o, loss = self._step_impl(p, o, tok)
                return (p, o), loss
            (p, _), _ = jax.lax.scan(step, (params, opt_state), tok_seq)
            return p
        return jax.vmap(one_client)(tokens)

    def local_train_batch(self, params, client_ids, rnd_seed: int, *,
                          wrap=None):
        """One jitted vmapped scan over all clients' local epochs; batch
        streams are identical to the looped ``local_train``.  ``wrap``
        is the distributed-engine hook (see ``CNNTrainer``)."""
        if self._custom_step:
            raise NotImplementedError(
                "custom step_fn (pjit) trainers use the looped path")
        toks = np.stack([
            np.stack([self._batch(self.client_toks[c], rnd_seed * 131 + ep)
                      for ep in range(self.fl.local_epochs)])
            for c in client_ids])                   # (C, E, B, S)
        run = (self._batch_train if wrap is None
               else wrap(self._batch_train_impl, 1))
        stacked = run(params, jnp.asarray(toks))
        sizes = np.asarray([len(self.client_toks[c]) for c in client_ids],
                           np.float32)
        return stacked, sizes

    def _batch_train_multi_impl(self, start_params, tokens):
        """tokens (C, E, B, S), start_params stacked (C, ...): every
        client trains from its own snapshot."""
        def one_client(p0, tok_seq):
            opt_state = self.opt.init(p0)
            def step(carry, tok):
                p, o = carry
                p, o, loss = self._step_impl(p, o, tok)
                return (p, o), loss
            (p, _), _ = jax.lax.scan(step, (p0, opt_state), tok_seq)
            return p
        return jax.vmap(one_client)(start_params, tokens)

    def local_train_cohort(self, start_params, client_ids, rnd_seeds, *,
                           wrap=None):
        """Async-window cohort: per-client start models and per-client
        seeds; batch streams identical to looping
        ``local_train(start_i, c_i, seed_i)``."""
        if self._custom_step:
            raise NotImplementedError(
                "custom step_fn (pjit) trainers use the looped path")
        toks = np.stack([
            np.stack([self._batch(self.client_toks[c], s * 131 + ep)
                      for ep in range(self.fl.local_epochs)])
            for c, s in zip(client_ids, rnd_seeds)])    # (C, E, B, S)
        run = (self._batch_train_multi if wrap is None
               else wrap(self._batch_train_multi_impl, 0))
        stacked = run(start_params, jnp.asarray(toks))
        sizes = np.asarray([len(self.client_toks[c]) for c in client_ids],
                           np.float32)
        return stacked, sizes

    def evaluate(self, params) -> float:
        b = jnp.asarray(self._batch(self.test_toks, 1234))
        return float(self._eval(params, b))


def build_fl_clients(arch_id: str, fl: FLConfig, dataset: Optional[str] = None,
                     scale: float = 0.05, reduced: bool = True):
    """Factory: any registered arch becomes an FL workload."""
    from repro.config import get_arch
    cfg = get_arch(arch_id)
    if cfg.family == "cnn":
        ds = dataset or {"cnn-mnist": "mnist", "cnn-fmnist": "fmnist",
                         "resnet8-cifar10": "cifar10"}[arch_id]
        return CNNTrainer(cfg, fl, ds, scale=scale)
    if reduced:
        cfg = cfg.reduced()
    return LMTrainer(cfg, fl)
