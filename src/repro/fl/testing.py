"""Synthetic trainers for runtime tests and server-step benchmarks.

``SyntheticCohortTrainer`` implements the FULL batched trainer
contract — ``init_params`` / ``local_train`` / jitted
``local_train_cohort`` with the distributed engine's ``wrap=`` hook /
``evaluate`` — with a deterministic elementwise update and zero
model-compile cost, so harnesses can exercise the engine/runtime/store
hot paths (snapshot gather vs stack, fused merges, history parity)
without a CNN/LM in the loop.  One definition keeps the parity tests
(``tests/test_state.py``) and the CI benchmark gate
(``benchmarks/bench_store.py``) tracking the trainer contract in
lockstep.
"""

from __future__ import annotations

from types import SimpleNamespace
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticCohortTrainer:
    """Deterministic multi-leaf trainer: the local "training" step adds
    a per-(client, seed) scalar to every leaf.

    ``leaf_specs`` maps leaf name -> (shape, dtype); the default is a
    small mixed-dtype tree (f32 matrix, bf16 vector, f32 scalar) that
    exercises exact store round-trips.  ``local_train`` and the
    vmappable ``local_train_cohort`` apply the same update, so looped
    and batched paths agree.
    """

    DEFAULT_SPECS: Dict[str, Tuple[tuple, object]] = {
        "w": ((4, 3), jnp.float32),
        "b": ((6,), jnp.bfloat16),
        "s": ((), jnp.float32),
    }

    def __init__(self, leaf_specs: Optional[Dict] = None, *,
                 arch_id: str = "synthetic", d_client: float = 0.01,
                 d_seed: float = 0.001, seed_mod: int = 7):
        self.leaf_specs = dict(leaf_specs or self.DEFAULT_SPECS)
        self.cfg = SimpleNamespace(arch_id=arch_id)
        self.d_client, self.d_seed = float(d_client), float(d_seed)
        self.seed_mod = int(seed_mod)
        self._cohort = jax.jit(self._cohort_impl)

    @classmethod
    def many_leaf(cls, n_leaves: int = 24, leaf: int = 256,
                  **kw) -> "SyntheticCohortTrainer":
        """Benchmark shape: many uniform f32 leaves, so leaf-by-leaf
        snapshot stacking cost dominates the dict-of-pytrees arm."""
        specs = {f"l{i:02d}": ((leaf,), jnp.float32)
                 for i in range(n_leaves)}
        kw.setdefault("arch_id", "manyleaf")
        kw.setdefault("d_client", 1e-3)
        kw.setdefault("d_seed", 1e-4)
        kw.setdefault("seed_mod", 13)
        return cls(specs, **kw)

    def init_params(self, seed: int = 0):
        rng = np.random.default_rng(seed)
        return {name: jnp.asarray(rng.normal(size=shape)
                                  .astype(np.float32)).astype(dtype)
                for name, (shape, dtype) in self.leaf_specs.items()}

    def _delta(self, client_id: int, rnd_seed: int) -> float:
        return ((client_id + 1) * self.d_client
                + (rnd_seed % self.seed_mod) * self.d_seed)

    def local_train(self, params, client_id: int, rnd_seed: int):
        d = jnp.float32(self._delta(client_id, rnd_seed))
        out = jax.tree_util.tree_map(
            lambda l: (l.astype(jnp.float32) + d).astype(l.dtype), params)
        return out, 10.0 + client_id

    def _cohort_impl(self, starts, d):
        return jax.tree_util.tree_map(
            lambda l: (l.astype(jnp.float32)
                       + d.reshape((-1,) + (1,) * (l.ndim - 1))
                       ).astype(l.dtype), starts)

    def local_train_cohort(self, start_params, client_ids, rnd_seeds, *,
                           wrap=None):
        d = jnp.asarray(np.asarray(
            [self._delta(c, s) for c, s in zip(client_ids, rnd_seeds)],
            np.float32))
        run = self._cohort if wrap is None else wrap(self._cohort_impl, 0)
        stacked = run(start_params, d)
        sizes = np.asarray([10.0 + c for c in client_ids], np.float32)
        return stacked, sizes

    def evaluate(self, params) -> float:
        leaves = [np.asarray(l, np.float32).ravel()
                  for l in jax.tree_util.tree_leaves(params)]
        return float(np.tanh(np.abs(np.concatenate(leaves)).mean()))
