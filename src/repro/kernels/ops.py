"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True``;
on TPU they compile natively.  ``gqa_flash_attention`` adapts the model
zoo's (B,S,H,D)/(B,T,Hkv,D) layout to the kernel's folded-head layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fedagg import fedagg
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def gqa_flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                        block_q=128, block_k=128, interpret=None):
    """q (B,S,H,D); k/v (B,T,Hkv,D) -> (B,S,H,D)."""
    interpret = on_cpu() if interpret is None else interpret
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    kx = jnp.repeat(k, rep, axis=2)
    vx = jnp.repeat(v, rep, axis=2)
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, t.shape[1], d)
    o = flash_attention(fold(q), fold(kx), fold(vx), causal=causal,
                        window=window, q_offset=q_offset, block_q=block_q,
                        block_k=block_k, interpret=interpret)
    return jnp.moveaxis(o.reshape(b, h, s, d), 1, 2)


def ssm_scan_op(x, dt, b_in, c_out, a_log, *, chunk=128, block_d=256,
                interpret=None):
    interpret = on_cpu() if interpret is None else interpret
    return ssm_scan(x, dt, b_in, c_out, a_log, chunk=chunk, block_d=block_d,
                    interpret=interpret)


def fedagg_op(updates, weights, *, block_p=16384, interpret=None):
    interpret = on_cpu() if interpret is None else interpret
    return fedagg(updates, weights, block_p=block_p, interpret=interpret)


def fedagg_pytree(stacked_updates, weights, *, interpret=None):
    """Weighted-average a pytree whose leaves are stacked (N, ...)."""
    def agg(leaf):
        n = leaf.shape[0]
        flat = leaf.reshape(n, -1)
        return fedagg_op(flat, weights, interpret=interpret).reshape(
            leaf.shape[1:])
    return jax.tree_util.tree_map(agg, stacked_updates)
