"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True``;
on TPU they compile natively.  ``gqa_flash_attention`` adapts the model
zoo's (B,S,H,D)/(B,T,Hkv,D) layout to the kernel's folded-head layout.

``fedagg_pytree`` is the pytree-native server aggregation hot path: the
stacked client-update pytree is flattened ONCE into a single (N, P)
f32 buffer (unflatten spec cached per tree structure), reduced by the
fused fedagg kernel in one pass, and split back — instead of one kernel
launch per leaf.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fedagg import fedagg, fedagg_fold, fedagg_partial
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def gqa_flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                        block_q=128, block_k=128, interpret=None):
    """q (B,S,H,D); k/v (B,T,Hkv,D) -> (B,S,H,D)."""
    interpret = on_cpu() if interpret is None else interpret
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    kx = jnp.repeat(k, rep, axis=2)
    vx = jnp.repeat(v, rep, axis=2)
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, t.shape[1], d)
    o = flash_attention(fold(q), fold(kx), fold(vx), causal=causal,
                        window=window, q_offset=q_offset, block_q=block_q,
                        block_k=block_k, interpret=interpret)
    return jnp.moveaxis(o.reshape(b, h, s, d), 1, 2)


def ssm_scan_op(x, dt, b_in, c_out, a_log, *, chunk=128, block_d=256,
                interpret=None):
    interpret = on_cpu() if interpret is None else interpret
    return ssm_scan(x, dt, b_in, c_out, a_log, chunk=chunk, block_d=block_d,
                    interpret=interpret)


def fedagg_op(updates, weights, *, alphas=None, block_p=16384,
              interpret=None):
    interpret = on_cpu() if interpret is None else interpret
    return fedagg(updates, weights, alphas=alphas, block_p=block_p,
                  interpret=interpret)


# ---------------------------------------------------------------------------
# Pytree-native aggregation: flatten once, one kernel pass, cached spec
# ---------------------------------------------------------------------------

# treedef + leaf (shape, dtype) signature -> list of (offset, size, shape,
# dtype) describing how to slice the flat (P,) result back into leaves.
_UNFLATTEN_SPECS: Dict[tuple, List[Tuple[int, int, tuple, object]]] = {}


def _unflatten_spec(treedef, leaves):
    key = (treedef, tuple((l.shape, str(l.dtype)) for l in leaves))
    spec = _UNFLATTEN_SPECS.get(key)
    if spec is None:
        spec, off = [], 0
        for l in leaves:
            size = int(np.prod(l.shape[1:], dtype=np.int64)) if l.ndim > 1 \
                else 1
            spec.append((off, size, l.shape[1:], l.dtype))
            off += size
        _UNFLATTEN_SPECS[key] = spec
    return spec


def flatten_updates(stacked):
    """Stacked pytree (leaves (N, ...)) -> ((N, P) f32 buffer, treedef,
    unflatten spec).  The spec is cached per (structure, shapes, dtypes)
    so repeated rounds pay only for the concat itself."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    if not leaves:
        raise ValueError("empty pytree: nothing to aggregate")
    spec = _unflatten_spec(treedef, leaves)
    n = leaves[0].shape[0]
    buf = jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)
    return buf, treedef, spec


def unflatten_result(flat, treedef, spec):
    """(P,) flat aggregate -> pytree with per-leaf shapes/dtypes restored."""
    outs = [flat[off:off + size].reshape(shape).astype(dtype)
            for off, size, shape, dtype in spec]
    return jax.tree_util.tree_unflatten(treedef, outs)


# Unstacked variant: a single model pytree <-> one flat (P,) f32 row —
# the ``ClientStateStore`` convention (a client snapshot is one row of
# the (N, P) store buffer).  Spec cache shared-format with the stacked
# path: (offset, size, full leaf shape, dtype).
_TREE_SPECS: Dict[tuple, List[Tuple[int, int, tuple, object]]] = {}


def tree_spec(tree):
    """-> (treedef, [(offset, size, shape, dtype)], total P) for an
    UNSTACKED pytree (no leading client axis).  Cached per structure."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("empty pytree: nothing to flatten")
    key = (treedef, tuple((tuple(l.shape), str(jnp.asarray(l).dtype))
                          for l in leaves))
    cached = _TREE_SPECS.get(key)
    if cached is None:
        spec, off = [], 0
        for l in leaves:
            size = int(np.prod(np.shape(l), dtype=np.int64))
            spec.append((off, size, tuple(np.shape(l)),
                         jnp.asarray(l).dtype))
            off += size
        cached = (spec, off)
        _TREE_SPECS[key] = cached
    spec, total = cached
    return treedef, spec, total


def fedagg_pytree(stacked_updates, weights, *, alphas=None, block_p=16384,
                  interpret=None):
    """Weighted-average a pytree whose leaves are stacked (N, ...).

    Zero-weight rows (masked stragglers) contribute exactly nothing —
    the mask is fused into the kernel, so callers can keep dropped
    clients in the stacked buffer instead of re-packing it.  ``alphas``
    adds per-row staleness coefficients (effective weight
    ``w_c * alpha_c``); a zero-alpha row is masked like a zero weight.
    """
    interpret = on_cpu() if interpret is None else interpret
    buf, treedef, spec = flatten_updates(stacked_updates)
    flat = fedagg(buf, weights, alphas=alphas, block_p=block_p,
                  interpret=interpret)
    return unflatten_result(flat, treedef, spec)


def flatten_params_row(params):
    """Model pytree -> (P,) f32 row in ``flatten_updates`` leaf order
    (no leading client axis) — the global-row companion of the stacked
    (N, P) buffer.  Kept jit-traceable (callers fuse it into their own
    programs)."""
    return jnp.concatenate(
        [jnp.asarray(l).reshape(-1).astype(jnp.float32)
         for l in jax.tree_util.tree_leaves(params)])


# ---------------------------------------------------------------------------
# Quantized row views: shifted-scale int8 segments with fused scales
# ---------------------------------------------------------------------------

# int8 grid radius and range divisor.  253 steps (not 254) leave half a
# step of slack on each side of the value range, so snapping the
# zero-point onto the quantization grid can never push a rounded index
# past +/-127 — the round-trip error bound |x - dq(q(x))| <= scale/2
# holds without the clip ever truncating an in-range value.
QUANT_QMAX = 127.0
QUANT_STEPS = 253.0


def quantize_rows(frows, segs):
    """f32 rows -> (int8 rows, per-segment scale/zero-point meta).

    ``frows`` is (..., Pf) f32; ``segs`` is a static tuple of
    ``(offset, size)`` float-segment views covering the row (the
    store's per-leaf layout).  Returns ``(qrows (..., Pf) int8,
    meta (..., 2L) f32)`` with ``meta[..., j]`` = scale and
    ``meta[..., L+j]`` = the SNAP INDEX of segment ``j`` — the
    zero-point expressed in grid steps (``zp = scale * snap``).

    Shifted-scale scheme, per (row, segment): ``scale = range/253``
    and the zero-point is the range midpoint snapped onto the
    quantization grid (``snap = round(mid/scale)``).  Dequantization
    is ``(q + snap) * scale``: storing the snap index rather than the
    zero-point keeps that an add FEEDING a multiply — not the
    ``a*b + c`` shape XLA contracts into an FMA (it fuses straight
    through ``optimization_barrier`` on CPU) — so dequantized bits are
    identical across compilation units and exactly match the numpy
    oracle, and exact zeros round-trip exactly on every backend
    (``q + snap == 0 -> 0 * scale == 0``; 0 in [lo, hi] bounds
    ``|snap| <= 126``, inside the clip range).  Constant segments
    (range 0) take scale=1, snap=value — an exact round-trip.  Every
    reduction here is a per-segment min/max (order-independent), so
    quantized bits are identical across batch shapes — the property
    that keeps dense-quant and tiered-quant histories bit-identical.
    """
    qs, scales, snaps = [], [], []
    for off, size in segs:
        x = frows[..., off:off + size]
        lo, hi = x.min(axis=-1), x.max(axis=-1)
        rng = hi - lo
        flat0 = rng <= 0.0
        # explicit reciprocal multiply: XLA strength-reduces division
        # by a constant to exactly this, so spelling it out pins the
        # f32 semantics across backends AND keeps the numpy oracle
        # (ref.quantize_rows_ref) bit-exact without mimicking an
        # optimizer pass
        scale = jnp.where(flat0, jnp.float32(1.0),
                          rng * jnp.float32(1.0 / QUANT_STEPS))
        snap = jnp.where(flat0, lo,
                         jnp.round((lo + hi) / (2.0 * scale)))
        zp = scale * snap
        q = jnp.clip(jnp.round((x - zp[..., None]) / scale[..., None]),
                     -QUANT_QMAX, QUANT_QMAX).astype(jnp.int8)
        qs.append(q)
        scales.append(scale)
        snaps.append(snap)
    qrows = jnp.concatenate(qs, axis=-1)
    meta = jnp.stack(scales + snaps, axis=-1)
    return qrows, meta


def dequantize_rows(qrows, meta, segs):
    """Inverse row view of ``quantize_rows``: (..., Pf) int8 rows plus
    (..., 2L) scale/snap meta -> (..., Pf) f32 rows.  Pure elementwise
    ``(q + snap) * scale`` per segment — an add feeding a multiply has
    no FMA contraction to vary by compilation unit, so the bits are
    stable across batch shapes, programs and the numpy oracle (see
    ``quantize_rows``)."""
    n = len(segs)
    outs = []
    for j, (off, size) in enumerate(segs):
        q = qrows[..., off:off + size].astype(jnp.float32)
        outs.append((q + meta[..., n + j, None]) * meta[..., j, None])
    return jnp.concatenate(outs, axis=-1)


def dequantize_segment(qrows, meta, segs, j):
    """One segment's dequantized f32 view (``segs[j]`` of ``qrows``) —
    the per-leaf form the store's fused gather slices directly into
    leaf shapes, skipping the full-row concat."""
    off, size = segs[j]
    q = qrows[..., off:off + size].astype(jnp.float32)
    return (q + meta[..., len(segs) + j, None]) * meta[..., j, None]


def fedagg_fold_op(updates, g, coef, *, block_p=16384, interpret=None):
    interpret = on_cpu() if interpret is None else interpret
    return fedagg_fold(updates, g, coef, block_p=block_p,
                       interpret=interpret)


def fedagg_partial_op(updates, coef, *, block_p=16384, interpret=None):
    interpret = on_cpu() if interpret is None else interpret
    return fedagg_partial(updates, coef, block_p=block_p,
                          interpret=interpret)


def fedagg_fold_pytree(global_params, stacked_updates, coef, *,
                       block_p=16384, interpret=None):
    """Folded staleness window merge over pytrees: ONE kernel pass on
    the flattened (K, P) client-row buffer with the global model as the
    IMPLICIT row 0 (its (P,) row rides in directly — no (K+1, ...)
    concatenated copy).

    This is the SHARED merge program of the async runtime's kernel
    path: both the dict-of-pytrees reference and the store-backed fused
    window step call it on identically-flattened buffers, which is what
    makes their histories bit-identical.  ``coef`` is the (K+1,)
    ``staleness_merge_coefficients`` vector (global first); padded /
    masked rows carry coefficient 0 and contribute exactly nothing.
    """
    interpret = on_cpu() if interpret is None else interpret
    buf, treedef, spec = flatten_updates(stacked_updates)
    g_flat = flatten_params_row(global_params)
    flat = fedagg_fold(buf, g_flat, coef, block_p=block_p,
                       interpret=interpret)
    out = unflatten_result(flat, treedef, spec)
    return jax.tree_util.tree_map(
        lambda g, m: m.astype(g.dtype), global_params, out)
