"""Flash attention Pallas TPU kernel: blockwise online softmax.

Grid (BH, nq, nk) with the KV dimension innermost/sequential; running
(acc, m, l) live in VMEM scratch across KV steps.  Block shapes default to
MXU-aligned (128, 128) tiles; q/k/v blocks are staged HBM->VMEM by
BlockSpec.  Causal and sliding-window masks are applied from absolute
positions so the same kernel serves full, causal, and SWA attention.

Heads are folded into the batch dimension (BH = B*H); GQA callers repeat
KV per group in the ops.py wrapper.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, causal: bool, window: int, q_offset: int,
            nk: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                   # (bq, d)
    k = k_ref[0].astype(jnp.float32)                   # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset  # fedlint: disable=FED003 -- int32 index arithmetic, exact regardless of FMA contraction
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)  # fedlint: disable=FED003 -- int32 index arithmetic, exact regardless of FMA contraction
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)  # fedlint: disable=FED003 -- online-softmax rescale; kernel is tolerance-tested vs the reference, not bit-identity-gated
    acc_ref[...] = (acc_ref[...] * corr[:, None]  # fedlint: disable=FED003 -- online-softmax rescale; kernel is tolerance-tested vs the reference, not bit-identity-gated
                    + jax.lax.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q (BH,S,D), k/v (BH,T,D) -> (BH,S,D)."""
    bh, s, d = q.shape
    t = k.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    if s % bq or t % bk:
        raise ValueError(f"S={s}/T={t} must divide block_q={bq}/block_k={bk}")
    nq, nk = s // bq, t // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, causal=causal, window=window,
        q_offset=q_offset, nk=nk, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
