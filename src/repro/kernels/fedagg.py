"""Weighted federated aggregation Pallas TPU kernel.

The FedDCT server's hot loop: w_global = sum_c (s_c / sum s) * w_c over
the stacked client updates (N_clients, P).  One pass over HBM, f32
accumulation in VMEM, parameter axis tiled so each (N, bp) panel fits
VMEM regardless of model size.  Weight normalization AND straggler
masking are fused: a zero-weight row (a dropped/straggling client) is
zeroed inside the kernel before the reduction, so non-finite garbage in
masked rows can never poison the average and the scheduler never has to
re-pack the stacked buffer after a drop.

The async runtime adds a per-row ``alphas`` vector (staleness merge
coefficients): the effective row weight is ``w_c * alpha_c``, so a
zero-alpha row (a fully-stale / masked client) is a straggler exactly
like a zero-weight row.  ``alphas=None`` keeps the original FedAvg
semantics (all ones).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(u_ref, w_ref, a_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)          # (N, bp)
    w = w_ref[...].astype(jnp.float32)          # (N,)
    a = a_ref[...].astype(jnp.float32)          # (N,)
    w = w * a                                   # staleness-discounted weight
    # fused straggler mask: zero-weight / zero-alpha clients contribute
    # exactly 0, even if their update row is inf/nan (never trained).
    u = jnp.where((w > 0.0)[:, None], u, 0.0)
    w = jnp.where(w > 0.0, w, 0.0)
    w = w / jnp.maximum(w.sum(), 1e-30)
    o_ref[...] = (w @ u).astype(o_ref.dtype)    # (bp,)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def _fedagg_call(updates, weights, alphas, block_p, interpret):
    n, p = updates.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    np_ = updates.shape[1]

    out = pl.pallas_call(
        _kernel,
        grid=(np_ // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), updates.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(updates, weights, alphas)
    return out[:p] if pad else out


def fedagg(updates, weights, *, alphas=None, block_p: int = 16384,
           interpret: bool = False):
    """updates (N,P), weights (N,) -> weighted average (P,).

    ``sum_c eff_c * u_c / sum(eff)`` with ``eff_c = w_c * alpha_c``
    (``alphas=None`` -> all ones).  Rows with ``eff_c <= 0`` are masked
    out (see module docstring); if every effective weight is zero the
    result is all-zeros.
    """
    if alphas is None:
        alphas = jnp.ones_like(weights, dtype=jnp.float32)
    return _fedagg_call(updates, weights, alphas, block_p, interpret)
