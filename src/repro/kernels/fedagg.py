"""Weighted federated aggregation Pallas TPU kernel.

The FedDCT server's hot loop: w_global = sum_c (s_c / sum s) * w_c over
the stacked client updates (N_clients, P).  One pass over HBM, f32
accumulation in VMEM, parameter axis tiled so each (N, bp) panel fits
VMEM regardless of model size.  Weight normalization AND straggler
masking are fused: a zero-weight row (a dropped/straggling client) is
zeroed inside the kernel before the reduction, so non-finite garbage in
masked rows can never poison the average and the scheduler never has to
re-pack the stacked buffer after a drop.

The async runtime adds a per-row ``alphas`` vector (staleness merge
coefficients): the effective row weight is ``w_c * alpha_c``, so a
zero-alpha row (a fully-stale / masked client) is a straggler exactly
like a zero-weight row.  ``alphas=None`` keeps the original FedAvg
semantics (all ones).

Two companions serve the async-runtime merge paths:

* ``fedagg_fold`` — the folded-row-0 staleness window merge: client
  rows (K, P) plus the current global row (P,) and the telescoped
  coefficient vector (K+1,) with the global model as the IMPLICIT row
  0, so no (K+1, P) concatenated copy is ever materialized.  The row
  reduction is a masked multiply + sum (not a dot) so appending
  zero-coefficient rows — the engine's padded cohort buckets — is a
  bitwise no-op, which is what lets the store-backed fused window step
  and the dict-of-pytrees reference produce bit-identical histories.
* ``fedagg_partial`` — the UNNORMALIZED masked row-sum
  ``sum_c c_c * u_c`` over one shard's rows: the per-shard term of the
  client-sharded psum reductions (``repro.distributed.aggregate``),
  same masking convention, normalization left to the caller's psum'd
  denominator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(u_ref, w_ref, a_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)          # (N, bp)
    w = w_ref[...].astype(jnp.float32)          # (N,)
    a = a_ref[...].astype(jnp.float32)          # (N,)
    w = w * a                                   # staleness-discounted weight
    # fused straggler mask: zero-weight / zero-alpha clients contribute
    # exactly 0, even if their update row is inf/nan (never trained).
    u = jnp.where((w > 0.0)[:, None], u, 0.0)
    w = jnp.where(w > 0.0, w, 0.0)
    w = w / jnp.maximum(w.sum(), 1e-30)
    o_ref[...] = (w @ u).astype(o_ref.dtype)    # (bp,)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def _fedagg_call(updates, weights, alphas, block_p, interpret):
    n, p = updates.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    np_ = updates.shape[1]

    out = pl.pallas_call(
        _kernel,
        grid=(np_ // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), updates.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(updates, weights, alphas)
    return out[:p] if pad else out


def fedagg(updates, weights, *, alphas=None, block_p: int = 16384,
           interpret: bool = False):
    """updates (N,P), weights (N,) -> weighted average (P,).

    ``sum_c eff_c * u_c / sum(eff)`` with ``eff_c = w_c * alpha_c``
    (``alphas=None`` -> all ones).  Rows with ``eff_c <= 0`` are masked
    out (see module docstring); if every effective weight is zero the
    result is all-zeros.
    """
    if alphas is None:
        alphas = jnp.ones_like(weights, dtype=jnp.float32)
    return _fedagg_call(updates, weights, alphas, block_p, interpret)


def _fold_kernel(u_ref, g_ref, c_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)          # (K, bp) client rows
    gt = g_ref[...].astype(jnp.float32)         # (bp,)  global row tile
    c = c_ref[...].astype(jnp.float32)          # (K+1,) [global, rows]
    c = jnp.where(c > 0.0, c, 0.0)
    c = c / jnp.maximum(c.sum(), 1e-30)
    c0, cr = c[0], c[1:]
    # fused straggler/pad mask: zero-coefficient rows contribute exactly
    # nothing even when their update row is inf/nan.
    u = jnp.where((cr > 0.0)[:, None], u, 0.0)
    g_term = jnp.where(c0 > 0.0, c0 * gt, 0.0)
    # masked multiply + row-axis sum, NOT a dot: appending zero rows
    # (padded cohort buckets) appends exact +0.0 terms to a sequential
    # reduction, keeping padded and unpadded windows bitwise equal.
    o_ref[...] = (g_term
                  + jnp.sum(u * cr[:, None], axis=0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def _fedagg_fold_call(updates, g, coef, block_p, interpret):
    n, p = updates.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
        g = jnp.pad(g, (0, pad))
    np_ = updates.shape[1]

    out = pl.pallas_call(
        _fold_kernel,
        grid=(np_ // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((n + 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), updates.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(updates, g, coef)
    return out[:p] if pad else out


def fedagg_fold(updates, g, coef, *, block_p: int = 16384,
                interpret: bool = False):
    """Folded staleness window merge: updates (K,P), global row g (P,),
    coef (K+1,) -> merged row (P,).

    ``coef`` is ``staleness_merge_coefficients(alphas)`` order: the
    global model's telescoped coefficient first, then one entry per
    client row.  Coefficients are masked at <= 0 and renormalized
    in-kernel (the fedagg convention), so masked stragglers and padded
    rows contribute exactly nothing; if every coefficient is zero the
    result is all-zeros.
    """
    return _fedagg_fold_call(updates, g, jnp.asarray(coef, jnp.float32),
                             block_p, interpret)


def _partial_kernel(u_ref, c_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)          # (rows, bp)
    c = c_ref[...].astype(jnp.float32)          # (rows,)
    c = jnp.where(c > 0.0, c, 0.0)
    u = jnp.where((c > 0.0)[:, None], u, 0.0)
    o_ref[...] = jnp.sum(u * c[:, None], axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def _fedagg_partial_call(updates, coef, block_p, interpret):
    n, p = updates.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    np_ = updates.shape[1]

    out = pl.pallas_call(
        _partial_kernel,
        grid=(np_ // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), updates.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(updates, coef)
    return out[:p] if pad else out


def fedagg_partial(updates, coef, *, block_p: int = 16384,
                   interpret: bool = False):
    """UNNORMALIZED masked weighted row-sum ``sum_c c_c * u_c`` -> (P,).

    The per-shard term of the client-sharded psum reductions: rows with
    ``c_c <= 0`` are zeroed before the sum (straggler/padding mask),
    normalization is the caller's job (divide by the psum'd coefficient
    sum).  Runs per shard inside ``shard_map`` — interpret-mode on CPU,
    compiled on TPU, like every fedagg dispatch.
    """
    return _fedagg_partial_call(updates, jnp.asarray(coef, jnp.float32),
                                block_p, interpret)
