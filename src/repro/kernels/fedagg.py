"""Weighted federated aggregation Pallas TPU kernel.

The FedDCT server's hot loop: w_global = sum_c (s_c / sum s) * w_c over
the stacked client updates (N_clients, P).  One pass over HBM, f32
accumulation in VMEM, parameter axis tiled so each (N, bp) panel fits
VMEM regardless of model size.  Weight normalization is fused.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, w_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)          # (N, bp)
    w = w_ref[...].astype(jnp.float32)          # (N,)
    w = w / jnp.maximum(w.sum(), 1e-30)
    o_ref[...] = (w @ u).astype(o_ref.dtype)    # (bp,)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def fedagg(updates, weights, *, block_p: int = 16384,
           interpret: bool = False):
    """updates (N,P), weights (N,) -> weighted average (P,)."""
    n, p = updates.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    np_ = updates.shape[1]

    out = pl.pallas_call(
        _kernel,
        grid=(np_ // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), updates.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(updates, weights)
    return out[:p] if pad else out
