from repro.kernels.ops import (
    fedagg_fold_op,
    fedagg_fold_pytree,
    fedagg_op,
    fedagg_partial_op,
    fedagg_pytree,
    gqa_flash_attention,
    ssm_scan_op,
)

__all__ = ["gqa_flash_attention", "ssm_scan_op", "fedagg_op",
           "fedagg_pytree", "fedagg_fold_op", "fedagg_fold_pytree",
           "fedagg_partial_op"]
