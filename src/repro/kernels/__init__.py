from repro.kernels.ops import (
    gqa_flash_attention,
    ssm_scan_op,
    fedagg_op,
    fedagg_pytree,
)

__all__ = ["gqa_flash_attention", "ssm_scan_op", "fedagg_op", "fedagg_pytree"]
