"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """q (BH,S,D), k/v (BH,T,D) — heads pre-folded into batch."""
    d = q.shape[-1]
    s_ = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(q.shape[1]) + q_offset
    kp = jnp.arange(k.shape[1])
    m = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        m &= kp[None, :] <= qp[:, None]
    if window > 0:
        m &= kp[None, :] > qp[:, None] - window
    s_ = jnp.where(m[None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(x, dt, b_in, c_out, a_log):
    """Sequential oracle for the diagonal selective scan.

    x, dt (B,S,D); b_in, c_out (B,S,N); a_log (D,N).  Returns y (B,S,D).
    """
    a_neg = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[:, :, None] * a_neg[None])          # (B,D,N)
        dbx = (dtt * xt)[:, :, None] * bt[:, None, :]
        h = da * h + dbx  # fedlint: disable=FED003 -- SSM recurrence in the reference oracle; kernels are tolerance-gated against it, not bit-identity-gated
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    bsz, s, d = x.shape
    n = b_in.shape[-1]
    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b_in.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c_out.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def fedagg_ref(updates, weights, alphas=None):
    """updates (N,P), weights (N,) -> (P,) weighted average (f32 accum).

    Mirrors the kernel's fused straggler mask and optional per-row
    staleness coefficients: the effective row weight is
    ``w_c * alpha_c`` (``alphas=None`` -> all ones) and rows whose
    effective weight is <= 0 are zeroed before the reduction so
    non-finite garbage cannot leak in.
    """
    w = weights.astype(jnp.float32)
    if alphas is not None:
        w = w * alphas.astype(jnp.float32)
    u = jnp.where((w > 0.0)[:, None], updates.astype(jnp.float32), 0.0)
    w = jnp.where(w > 0.0, w, 0.0)
    w = w / jnp.maximum(w.sum(), 1e-30)
    return jnp.einsum("np,n->p", u, w).astype(updates.dtype)


def fedagg_fold_ref(updates, g, coef):
    """Oracle for ``fedagg_fold``: updates (K,P), g (P,), coef (K+1,)
    with the global row folded in as the implicit row 0."""
    c = coef.astype(jnp.float32)
    c = jnp.where(c > 0.0, c, 0.0)
    c = c / jnp.maximum(c.sum(), 1e-30)
    u = jnp.where((c[1:] > 0.0)[:, None], updates.astype(jnp.float32), 0.0)
    g_term = jnp.where(c[0] > 0.0, c[0] * g.astype(jnp.float32), 0.0)
    return (g_term + jnp.sum(u * c[1:, None], axis=0)).astype(updates.dtype)


def quantize_rows_ref(frows, segs):
    """Pure-numpy oracle for ``ops.quantize_rows`` (shifted-scale int8
    row views; meta carries scale + grid-snap index per segment).
    numpy's ``round`` is round-half-to-even like XLA's, every
    intermediate stays f32 (the reciprocal multiply mirrors XLA's
    strength-reduced constant division), and the math avoids FMA-
    contractible shapes — so the parity gate asserts exact equality."""
    import numpy as np
    frows = np.asarray(frows, np.float32)
    qs, scales, snaps = [], [], []
    for off, size in segs:
        x = frows[..., off:off + size]
        lo, hi = x.min(axis=-1), x.max(axis=-1)
        rng = hi - lo
        flat0 = rng <= 0.0
        scale = np.where(flat0, np.float32(1.0),
                         rng * np.float32(1.0 / 253.0)).astype(np.float32)
        snap = np.where(
            flat0, lo,
            np.round((lo + hi) / (np.float32(2.0) * scale))
        ).astype(np.float32)
        zp = (scale * snap).astype(np.float32)
        q = np.clip(np.round((x - zp[..., None]) / scale[..., None]),
                    -127.0, 127.0).astype(np.int8)
        qs.append(q)
        scales.append(scale)
        snaps.append(snap)
    return (np.concatenate(qs, axis=-1),
            np.stack(scales + snaps, axis=-1).astype(np.float32))


def dequantize_rows_ref(qrows, meta, segs):
    """Pure-numpy oracle for ``ops.dequantize_rows``:
    ``(q + snap) * scale`` per segment, all f32."""
    import numpy as np
    qrows = np.asarray(qrows)
    meta = np.asarray(meta, np.float32)
    n = len(segs)
    outs = []
    for j, (off, size) in enumerate(segs):
        q = qrows[..., off:off + size].astype(np.float32)
        outs.append((q + meta[..., n + j, None]) * meta[..., j, None])
    return np.concatenate(outs, axis=-1)


def fedagg_partial_ref(updates, coef):
    """Oracle for ``fedagg_partial``: unnormalized masked row-sum."""
    c = coef.astype(jnp.float32)
    c = jnp.where(c > 0.0, c, 0.0)
    u = jnp.where((c > 0.0)[:, None], updates.astype(jnp.float32), 0.0)
    return jnp.sum(u * c[:, None], axis=0).astype(updates.dtype)
