"""Diagonal selective-SSM scan Pallas TPU kernel.

Recurrence (per channel block, diagonal state):
    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
    y_t = <h_t, C_t>

Grid (B, nd, nt): channel blocks parallel, time chunks sequential; the
running state h (bd, N) stays resident in VMEM scratch across time chunks
(HBM traffic is only the input chunk + output chunk per step — this is
the whole point of the kernel vs. materializing (B,S,D,N) in HBM).
Within a chunk the recurrence steps serially over Q timesteps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, y_ref, h_ref, *,
            chunk: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a_neg = -jnp.exp(alog_ref[...].astype(jnp.float32))     # (bd, N)

    def step(i, h):
        xt = x_ref[0, i].astype(jnp.float32)                # (bd,)
        dtt = dt_ref[0, i].astype(jnp.float32)              # (bd,)
        bt = b_ref[0, i].astype(jnp.float32)                # (N,)
        ct = c_ref[0, i].astype(jnp.float32)                # (N,)
        da = jnp.exp(dtt[:, None] * a_neg)                  # (bd, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]  # fedlint: disable=FED003 -- SSM recurrence; kernel is tolerance-gated vs the numpy oracle, not bit-identity-gated
        y_ref[0, i] = (h @ ct).astype(y_ref.dtype)          # (bd,)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def ssm_scan(x, dt, b_in, c_out, a_log, *, chunk: int = 128,
             block_d: int = 256, interpret: bool = False):
    """x, dt (B,S,D); b_in, c_out (B,S,N); a_log (D,N) -> y (B,S,D)."""
    bsz, s, d = x.shape
    n = b_in.shape[-1]
    bd = min(block_d, d)
    q = min(chunk, s)
    if d % bd or s % q:
        raise ValueError(f"D={d}%{bd} or S={s}%{q} not divisible")
    nd, nt = d // bd, s // q

    kernel = functools.partial(_kernel, chunk=q)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nd, nt),
        in_specs=[
            pl.BlockSpec((1, q, bd), lambda b, j, t: (b, t, j)),
            pl.BlockSpec((1, q, bd), lambda b, j, t: (b, t, j)),
            pl.BlockSpec((1, q, n), lambda b, j, t: (b, t, 0)),
            pl.BlockSpec((1, q, n), lambda b, j, t: (b, t, 0)),
            pl.BlockSpec((bd, n), lambda b, j, t: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, bd), lambda b, j, t: (b, t, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b_in, c_out, a_log)
