from repro.roofline.analysis import (
    TPU_V5E,
    HWSpec,
    analyze_hlo,
    roofline_terms,
)

__all__ = ["analyze_hlo", "roofline_terms", "HWSpec", "TPU_V5E"]
