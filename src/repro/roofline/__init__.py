from repro.roofline.analysis import (
    analyze_hlo,
    roofline_terms,
    HWSpec,
    TPU_V5E,
)

__all__ = ["analyze_hlo", "roofline_terms", "HWSpec", "TPU_V5E"]
