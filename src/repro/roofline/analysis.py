"""Roofline analysis from compiled HLO.

``xla_hlo_cost_analysis`` (exposed via ``compiled.cost_analysis()``)
counts while-loop bodies ONCE, which under-reports layer-scanned models
by ~num_layers x.  So we parse the optimized HLO text ourselves:

  * per-computation: dot FLOPs (2 * output_elems * contraction) and
    collective bytes (max of operand/result bytes) by opcode;
  * call graph: fusion/call add cost once, while multiplies its body by
    the trip count recovered from the loop condition's bound constant;
  * ENTRY-rooted traversal avoids double counting.

Roofline terms (seconds, per chip):
  compute    = FLOPs / (chips * peak)
  memory     = bytes_accessed / (chips * hbm_bw)   [cost_analysis value,
               scaled by scan trip ratio when the HLO is layer-scanned]
  collective = collective_bytes / (chips * ici_bw)
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# Ring-algorithm wire-cost weights (bytes actually moved per link, in
# units of the tensor size): all-reduce = reduce-scatter + all-gather.
# Without this, sequence-parallelism (which converts all-reduces into
# all-gather + reduce-scatter pairs at half the wire cost) measures as a
# regression — see EXPERIMENTS.md §Perf llama iteration v1 vs v6.
_COLL_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class CompCost:
    dot_flops: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    calls: List[Tuple[str, float]] = field(default_factory=list)  # (comp, mult)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    name = None
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m and line.rstrip().endswith("{"):
                name = m.group(1)
                comps[name] = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = comps[name]
                continue
            name = None
        elif name is not None:
            comps[name].append(line.strip())
    return comps


def _instr_defs(lines: List[str]) -> Dict[str, str]:
    """name -> full type string of each instruction definition."""
    defs = {}
    for ln in lines:
        m = re.match(r"(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s", ln)
        if m:
            defs[m.group(1)] = m.group(2)
    return defs


def _operand_names(operand_str: str) -> List[str]:
    """Operand instruction names, robust to typed operands — newer HLO
    prints ``dot(f32[8,32]{1,0} %lhs, ...)`` (commas inside the type
    make naive splitting wrong)."""
    return re.findall(r"%([\w.\-]+)", operand_str)


def _operand_dims(operand_str: str, idx: int, defs: Dict[str, str]
                  ) -> List[int]:
    names = _operand_names(operand_str)
    if idx < len(names) and names[idx] in defs:
        return _shape_dims(defs[names[idx]])
    # fall back to the inline type annotation of the idx-th operand
    typed = re.findall(r"(\w+\[[\d,]*\])", operand_str)
    if idx < len(typed):
        return _shape_dims(typed[idx])
    return []


def _dot_flops(ln: str, defs: Dict[str, str]) -> float:
    out_m = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\S+)\s+dot\(", ln)
    if not out_m:
        return 0.0
    out_elems = _shape_elems(out_m.group(1))
    ops = re.search(r"dot\((.*)\)", ln)
    lhs_dims = _operand_dims(ops.group(1), 0, defs)
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
    contraction = 1
    if cd and lhs_dims:
        for d in cd.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contraction *= lhs_dims[int(d)]
    return 2.0 * out_elems * contraction


def _conv_flops(ln: str, defs: Dict[str, str]) -> float:
    out_m = re.match(r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\S+)\s+convolution\(", ln)
    if not out_m:
        return 0.0
    out_elems = _shape_elems(out_m.group(1))
    ops = re.search(r"convolution\((.*)\)", ln)
    k_dims = _operand_dims(ops.group(1), 1, defs)
    if not k_dims:
        return 0.0
    k = 1
    for d in k_dims[:-1]:       # all but output-feature dim
        k *= d
    return 2.0 * out_elems * k


def _trip_count(cond_lines: List[str]) -> int:
    """Loop bound: the s32 constant compared against in the condition."""
    consts = []
    for ln in cond_lines:
        for m in re.finditer(r"s32\[\]\s+constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps = _split_computations(hlo)
    costs: Dict[str, CompCost] = {}
    cond_of_body: Dict[str, str] = {}

    for name, lines in comps.items():
        if name == "__entry__" and lines is not comps.get(name):
            continue
        cc = CompCost()
        defs = _instr_defs(lines)
        for ln in lines:
            if " dot(" in ln:
                cc.dot_flops += _dot_flops(ln, defs)
            elif " convolution(" in ln:
                cc.dot_flops += _conv_flops(ln, defs)
            mcoll = re.match(
                r"(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                r"collective-permute)(?:-start)?\(([^)]*)\)", ln)
            if mcoll:
                out_b = _shape_bytes(mcoll.group(1))
                in_b = 0
                for op in _operand_names(mcoll.group(3)):
                    in_b += _shape_bytes(defs.get(op, ""))
                kind = mcoll.group(2)
                cc.coll_bytes[kind] = cc.coll_bytes.get(kind, 0.0) + float(
                    max(out_b, in_b))
            # while operand may carry an inline tuple-type annotation
            mwhile = re.search(
                r"while\((?:\([^)]*\)\s*)?%[\w.\-]+\), condition=%([\w.\-]+),"
                r" body=%([\w.\-]+)", ln)
            if mwhile:
                cond, body = mwhile.group(1), mwhile.group(2)
                mknown = re.search(
                    r"known_trip_count\D*\"n\":\"(\d+)\"", ln)
                trips = int(mknown.group(1)) if mknown else _trip_count(
                    comps.get(cond, []))
                cc.calls.append((body, float(trips)))
            for mcall in re.finditer(r"calls=%([\w.\-]+)", ln):
                cc.calls.append((mcall.group(1), 1.0))
            mto = re.search(r"to_apply=%([\w.\-]+)", ln)
            if mto:
                cc.calls.append((mto.group(1), 1.0))
        costs[name] = cc

    memo: Dict[str, Tuple[float, Dict[str, float]]] = {}

    def total(name: str, depth=0) -> Tuple[float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in costs or depth > 50:
            return 0.0, {}
        cc = costs[name]
        fl = cc.dot_flops
        cb = dict(cc.coll_bytes)
        for child, mult in cc.calls:
            cfl, ccb = total(child, depth + 1)
            fl += mult * cfl
            for k, v in ccb.items():
                cb[k] = cb.get(k, 0.0) + mult * v
        memo[name] = (fl, cb)
        return memo[name]

    # find the ENTRY computation: the one not called by anyone
    called = {c for cc in costs.values() for c, _ in cc.calls}
    roots = [n for n in costs if n not in called and n != "__entry__"]
    fl_total, cb_total = 0.0, {}
    for r in roots:
        fl, cb = total(r)
        fl_total += fl
        for k, v in cb.items():
            cb_total[k] = cb_total.get(k, 0.0) + v
    return {
        "dot_flops": fl_total,
        "collective_bytes": sum(cb_total.values()),
        "collective_wire_bytes": sum(_COLL_WEIGHT[k] * v
                                     for k, v in cb_total.items()),
        "collective_breakdown": cb_total,
        "n_computations": len(costs),
    }


# ---------------------------------------------------------------------------
# Hardware + roofline terms
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HWSpec:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link


TPU_V5E = HWSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


def roofline_terms(*, hlo_flops: float, hbm_bytes: float,
                   collective_bytes: float, chips: int,
                   hw: HWSpec = TPU_V5E) -> Dict[str, float]:
    compute = hlo_flops / (chips * hw.peak_flops)
    memory = hbm_bytes / (chips * hw.hbm_bw)
    collective = collective_bytes / (chips * hw.ici_bw)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms
