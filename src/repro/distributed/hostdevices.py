"""Forced host-device-count plumbing (``XLA_FLAGS``) — no jax imports.

XLA locks the host platform device count at first backend
initialization, so ``--xla_force_host_platform_device_count`` must be
in ``XLA_FLAGS`` before anything runs a jax computation.  This module
owns that env manipulation for every entry point that wants a
multi-device CPU (``launch/dryrun.py``'s 512-chip dry-run,
``benchmarks/bench_shard.py``'s forced-8 A/B, the distributed CI job):

* ``ensure_host_device_count`` APPENDS the flag to whatever the caller
  already has in ``XLA_FLAGS`` — other flags (dump paths, cpu options)
  survive.  A pre-existing forced count wins: an explicit operator
  choice is never clobbered.  (The historical bug was ``dryrun.py``
  overwriting the whole variable at import.)
* ``forced_host_device_count`` reports the count currently in effect,
  which lets test collection decide whether the process is a dedicated
  multi-device run.
"""

from __future__ import annotations

import os
import re
from typing import MutableMapping, Optional

_FLAG = "--xla_force_host_platform_device_count"


def forced_host_device_count(
        env: Optional[MutableMapping[str, str]] = None) -> Optional[int]:
    """The forced host device count present in ``XLA_FLAGS``, or
    ``None`` when the flag is absent (the real device count applies)."""
    flags = (os.environ if env is None else env).get("XLA_FLAGS", "")
    m = re.search(re.escape(_FLAG) + r"=(\d+)", flags)
    return int(m.group(1)) if m else None


def ensure_host_device_count(
        n: int, env: Optional[MutableMapping[str, str]] = None) -> int:
    """Append ``--xla_force_host_platform_device_count=n`` to
    ``XLA_FLAGS``, preserving existing flags.

    If a forced count is already present it wins and is returned
    unchanged.  Returns the count now in effect.  Must run before jax
    initializes its backend (flag changes after that are ignored).
    """
    env = os.environ if env is None else env
    existing = forced_host_device_count(env)
    if existing is not None:
        return existing
    if n < 1:
        raise ValueError(f"host device count must be >= 1, got {n}")
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (flags + " " if flags else "") + f"{_FLAG}={int(n)}"
    return int(n)
