"""Client-sharded server aggregation: per-shard partial sums + one psum.

The single-device hot path (``weighted_average_stacked`` /
``fedagg_pytree``) reduces the whole flattened (N, P) update buffer on
one device.  Here each shard reduces only its own rows —
``sum_shard eff_c * u_c`` and ``sum_shard eff_c`` — and a single
``psum`` pair across the ``clients`` axis produces the global weighted
average.  That is the entire cross-device traffic of a round: one (P,)
all-reduce plus one scalar.

``use_kernel=True`` dispatches each shard's partial sum through the
Pallas ``fedagg_partial`` kernel instead of the jnp reduction — the
per-shard fedagg dispatch the ROADMAP names (interpret-mode on CPU,
compiled on TPU); the psum combine and the normalization are
unchanged, so the masking semantics are identical.

Numerics: identical masking semantics to the reference (rows with
``eff_c = w_c * alpha_c <= 0`` contribute exactly nothing; an
all-masked cohort yields zeros — or ``fallback`` when given), equal up
to float reassociation — partial sums reduce per-shard before the
psum, so results match the single-device reduction within dtype
tolerance, not bitwise.  ``sharded_staleness_merge`` rides the same
reduction with the PR 2 staleness coefficients, the global model as an
IMPLICIT row 0 (its telescoped coefficient multiplies the flattened
global row directly — no (K+1, ...) concatenated copy, matching the
folded single-device ``staleness_weighted_merge``).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.aggregation import staleness_merge_coefficients
from repro.distributed.plan import ClientShardingPlan
from repro.kernels.fedagg import fedagg_partial
from repro.kernels.ops import flatten_updates, on_cpu, unflatten_result

# (mesh, kernel dispatch) -> jitted shard_map reduction (meshes hash by
# device assignment, so one compiled program per distinct client mesh
# and dispatch mode)
_AGG_CACHE: Dict[tuple, object] = {}
_MERGE_CACHE: Dict[tuple, object] = {}


def _resolve_kernel(use_kernel, interpret):
    """Normalize the dispatch key: the jnp path ignores ``interpret``;
    the kernel path defaults it to interpret-mode on CPU."""
    if not use_kernel:
        return False, None
    return True, (on_cpu() if interpret is None else bool(interpret))


def _agg_fn(mesh, use_kernel: bool, interpret):
    key = (mesh, use_kernel, interpret)
    fn = _AGG_CACHE.get(key)
    if fn is None:
        axis = mesh.axis_names[0]

        def partial_reduce(u, w, a):
            # u (rows/D, P) f32, w/a (rows/D,): this shard's rows only.
            eff = w * a
            eff = jnp.where(eff > 0.0, eff, 0.0)
            # fused straggler/padding mask: a row with eff <= 0 is
            # zeroed BEFORE the reduction, so nonfinite garbage in
            # masked rows can never poison the average (the fedagg
            # kernel convention — the kernel fuses the same mask).
            if use_kernel:
                local = fedagg_partial(u, eff, interpret=interpret)
            else:
                masked = jnp.where((eff > 0.0)[:, None], u, 0.0)
                local = eff @ masked
            num = jax.lax.psum(local, axis)             # (P,)
            den = jax.lax.psum(eff.sum(), axis)         # scalar
            return num / jnp.maximum(den, 1e-30), den

        fn = jax.jit(shard_map(
            partial_reduce, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis)), out_specs=(P(), P()),
            check_rep=False))
        _AGG_CACHE[key] = fn
    return fn


def sharded_aggregate(mesh, stacked, weights, *, alphas=None,
                      fallback=None, use_kernel: bool = False,
                      interpret=None):
    """Client-sharded ``weighted_average_stacked``.

    ``stacked`` is a pytree whose leaves carry a leading client axis
    (N, ...); ``weights`` (N,) and optional ``alphas`` (N,) multiply
    into per-row effective weights.  The buffer is flattened once into
    (N, P) f32 (cached unflatten spec — the fedagg pytree convention),
    zero-padded to a multiple of the mesh size with zero effective
    weight (exact no-op rows), reduced per shard — through the Pallas
    ``fedagg_partial`` kernel when ``use_kernel`` — and combined by one
    psum.  Returns the aggregated pytree with per-leaf shapes/dtypes
    restored.

    ``fallback``: an optional per-row-shaped pytree (the global params)
    returned — via a device-side select, no host sync — when every
    effective weight is zero (the all-masked round).
    """
    buf, treedef, spec = flatten_updates(stacked)
    n = buf.shape[0]
    w = jnp.asarray(weights, jnp.float32).reshape(-1)
    a = (jnp.ones_like(w) if alphas is None
         else jnp.asarray(alphas, jnp.float32).reshape(-1))
    if w.shape[0] != n or a.shape[0] != n:
        raise ValueError(
            f"weights/alphas length {w.shape[0]}/{a.shape[0]} != rows {n}")
    plan = ClientShardingPlan.for_cohort(n, mesh)
    use_kernel, interpret = _resolve_kernel(use_kernel, interpret)
    flat, den = _agg_fn(mesh, use_kernel, interpret)(
        plan.pad_stacked(buf, mode="zero"),
        plan.pad_weights(w), plan.pad_weights(a))
    out = unflatten_result(flat, treedef, spec)
    if fallback is None:
        return out
    return jax.tree_util.tree_map(
        lambda m, p: jnp.where(den > 0.0, m.astype(p.dtype), p),
        out, fallback)


def _merge_fn(mesh, use_kernel: bool, interpret):
    key = (mesh, use_kernel, interpret)
    fn = _MERGE_CACHE.get(key)
    if fn is None:
        axis = mesh.axis_names[0]

        def partial_merge(u, c):
            # u (rows/D, P) f32, c (rows/D,) this shard's (already
            # normalized) merge coefficients; zero rows are padding or
            # masked stragglers — exact no-ops.
            if use_kernel:
                local = fedagg_partial(u, c, interpret=interpret)
            else:
                masked = jnp.where((c > 0.0)[:, None], u, 0.0)
                local = c @ masked
            return jax.lax.psum(local, axis)            # (P,)

        fn = jax.jit(shard_map(
            partial_merge, mesh=mesh,
            in_specs=(P(axis), P(axis)), out_specs=P(),
            check_rep=False))
        _MERGE_CACHE[key] = fn
    return fn


@jax.jit
def _fold_global(flat_sum, global_params, c0):
    # flatten of the global model rides inside the jit: one dispatch
    # per window, not one per leaf
    g_flat = jnp.concatenate(
        [l.reshape(-1).astype(jnp.float32)
         for l in jax.tree_util.tree_leaves(global_params)])
    g_term = jnp.where(c0 > 0.0, c0 * g_flat, 0.0)
    return g_term + flat_sum


def sharded_staleness_merge(mesh, global_params, stacked, alphas, *,
                            use_kernel: bool = False, interpret=None):
    """Client-sharded ``staleness_weighted_merge``: the async window
    merge as one sharded reduction over the client rows, the global
    model riding as an IMPLICIT row 0 — its telescoped coefficient
    multiplies the flattened global row directly instead of
    concatenating a (K+1, ...) copy through the mesh.  Zero-alpha rows
    (masked stragglers) contribute exactly nothing.  ``use_kernel``
    dispatches each shard's partial sum through the Pallas
    ``fedagg_partial`` kernel."""
    coef = staleness_merge_coefficients(alphas)
    # normalize host-side (the coefficients are host scalars already):
    # entries sum to 1 up to fp, mirroring the reference's in-program
    # normalization within reassociation tolerance.
    c = np.where(coef > 0.0, coef, 0.0).astype(np.float64)
    c = (c / max(c.sum(), 1e-30)).astype(np.float32)
    buf, treedef, spec = flatten_updates(stacked)
    n = buf.shape[0]
    plan = ClientShardingPlan.for_cohort(n, mesh)
    use_kernel, interpret = _resolve_kernel(use_kernel, interpret)
    flat_sum = _merge_fn(mesh, use_kernel, interpret)(
        plan.pad_stacked(buf, mode="zero"), plan.pad_weights(c[1:]))
    flat = _fold_global(flat_sum, global_params, jnp.float32(c[0]))
    merged = unflatten_result(flat, treedef, spec)
    # unflatten_result restores the STACKED leaves' dtypes; re-cast to
    # the global model's per-leaf dtypes (identical trees in practice)
    return jax.tree_util.tree_map(
        lambda g, m: m.astype(g.dtype), global_params, merged)
