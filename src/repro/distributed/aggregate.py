"""Client-sharded server aggregation: per-shard partial sums + one psum.

The single-device hot path (``weighted_average_stacked`` /
``fedagg_pytree``) reduces the whole flattened (N, P) update buffer on
one device.  Here each shard reduces only its own rows —
``sum_shard eff_c * u_c`` and ``sum_shard eff_c`` — and a single
``psum`` pair across the ``clients`` axis produces the global weighted
average.  That is the entire cross-device traffic of a round: one (P,)
all-reduce plus one scalar.

Numerics: identical masking semantics to the reference (rows with
``eff_c = w_c * alpha_c <= 0`` contribute exactly nothing; an
all-masked cohort yields zeros), equal up to float reassociation —
partial sums reduce per-shard before the psum, so results match the
single-device reduction within dtype tolerance, not bitwise.
``sharded_staleness_merge`` rides the same reduction with the PR 2
staleness coefficients (global model as row 0), exactly like
``staleness_weighted_merge`` does on one device.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.aggregation import staleness_merge_coefficients
from repro.distributed.plan import ClientShardingPlan
from repro.kernels.ops import flatten_updates, unflatten_result

# mesh -> jitted shard_map reduction (meshes hash by device assignment,
# so one compiled program per distinct client mesh)
_AGG_CACHE: Dict[object, object] = {}


def _agg_fn(mesh):
    fn = _AGG_CACHE.get(mesh)
    if fn is None:
        axis = mesh.axis_names[0]

        def partial_reduce(u, w, a):
            # u (rows/D, P) f32, w/a (rows/D,): this shard's rows only.
            eff = w * a
            eff = jnp.where(eff > 0.0, eff, 0.0)
            # fused straggler/padding mask: a row with eff <= 0 is
            # zeroed BEFORE the reduction, so nonfinite garbage in
            # masked rows can never poison the average (the fedagg
            # kernel convention).
            masked = jnp.where((eff > 0.0)[:, None], u, 0.0)
            num = jax.lax.psum(eff @ masked, axis)      # (P,)
            den = jax.lax.psum(eff.sum(), axis)         # scalar
            return num / jnp.maximum(den, 1e-30)

        fn = jax.jit(shard_map(
            partial_reduce, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis)), out_specs=P(),
            check_rep=False))
        _AGG_CACHE[mesh] = fn
    return fn


def sharded_aggregate(mesh, stacked, weights, *, alphas=None):
    """Client-sharded ``weighted_average_stacked``.

    ``stacked`` is a pytree whose leaves carry a leading client axis
    (N, ...); ``weights`` (N,) and optional ``alphas`` (N,) multiply
    into per-row effective weights.  The buffer is flattened once into
    (N, P) f32 (cached unflatten spec — the fedagg pytree convention),
    zero-padded to a multiple of the mesh size with zero effective
    weight (exact no-op rows), reduced per shard, and combined by one
    psum.  Returns the aggregated pytree with per-leaf shapes/dtypes
    restored.
    """
    buf, treedef, spec = flatten_updates(stacked)
    n = buf.shape[0]
    w = jnp.asarray(weights, jnp.float32).reshape(-1)
    a = (jnp.ones_like(w) if alphas is None
         else jnp.asarray(alphas, jnp.float32).reshape(-1))
    if w.shape[0] != n or a.shape[0] != n:
        raise ValueError(
            f"weights/alphas length {w.shape[0]}/{a.shape[0]} != rows {n}")
    plan = ClientShardingPlan.for_cohort(n, mesh)
    flat = _agg_fn(mesh)(plan.pad_stacked(buf, mode="zero"),
                         plan.pad_weights(w), plan.pad_weights(a))
    return unflatten_result(flat, treedef, spec)


def sharded_staleness_merge(mesh, global_params, stacked, alphas):
    """Client-sharded ``staleness_weighted_merge``: the async window
    merge as one sharded reduction, global model riding as row 0 with
    the telescoped merge coefficients (which sum to 1, so the
    normalization inside ``sharded_aggregate`` is a no-op).  Zero-alpha
    rows (masked stragglers) contribute exactly nothing."""
    coef = staleness_merge_coefficients(alphas)
    full = jax.tree_util.tree_map(
        lambda g, s: jnp.concatenate([g[None].astype(s.dtype), s], axis=0),
        global_params, stacked)
    ones = np.ones(coef.shape[0], np.float32)
    return sharded_aggregate(mesh, full, ones, alphas=coef)
