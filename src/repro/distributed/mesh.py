"""Client-axis mesh factory.

The FL client axis is embarrassingly parallel — TiFL-style tiers are
independent workers, and the wireless analyses assume per-device
compute — so the distributed engine shards cohorts over a 1-D
``("clients",)`` mesh.  This composes with the production factories in
``launch/mesh.py``: pass ``devices=mesh.devices.flatten()`` to carve
the client axis out of devices an existing mesh owns, or nothing to
span every visible device (on CPU CI that is whatever
``--xla_force_host_platform_device_count`` forced).

A function, not a module-level constant: importing this module never
touches jax device state (the same convention as ``launch/mesh.py``).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

CLIENT_AXIS = "clients"


def make_client_mesh(clients: Optional[int] = None, *,
                     devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """1-D ``("clients",)`` mesh over the first ``clients`` devices.

    ``clients=None`` spans every available device; a request larger
    than the device count is clamped (mirroring ``make_host_mesh``), so
    ``--mesh-clients 8`` degrades gracefully on a single-device box.
    """
    devs = list(devices if devices is not None else jax.devices())
    n = len(devs) if clients is None else int(clients)
    if n < 1:
        raise ValueError(f"client mesh needs at least one device, got {n}")
    n = min(n, len(devs))
    return jax.sharding.Mesh(np.asarray(devs[:n]), (CLIENT_AXIS,))
