"""Client-sharded execution engine: ``shard_map`` cohorts over a mesh.

``BatchedClientEngine`` (PR 1) made a cohort ONE vmapped device
program; this subclass makes cohort size scale with device count
instead of device memory.  Each client shard's snapshots, data batches
and rng-derived streams land on their own device, local epochs run
under ``shard_map`` with ZERO cross-device collectives (the client axis
is embarrassingly parallel), and the merge reduces per-shard partial
sums into a single psum (``repro.distributed.aggregate``).

Trainers opt in through the ``wrap`` hook of ``local_train_batch`` /
``local_train_cohort``: the trainer hands its pure stacked-train
function (plus how many leading args are replicated) to the engine,
which returns the shard_map-wrapped runner.  Trainers without the hook
— or without the batched paths at all — transparently fall back to the
inherited single-device semantics, so every scheduler keeps working
unmodified.

Pallas kernel aggregation (``use_kernel_agg``) dispatches each shard's
partial sum through the ``fedagg_partial`` kernel inside the psum
reduction (``repro.distributed.aggregate`` — interpret-mode on CPU,
compiled on TPU); the combine and normalization are unchanged, so the
flag changes how a shard reduces its own rows, not the semantics.

Single-device note: ``make_engine(..., mesh=<1-device mesh>)``
deliberately returns the plain ``BatchedClientEngine`` — the
distributed path with one device IS the existing engine, bit-identical
by construction rather than by tolerance.
"""

from __future__ import annotations

import inspect
from typing import Callable, Dict, Optional

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine import BatchedClientEngine
from repro.distributed.aggregate import (sharded_aggregate,
                                         sharded_staleness_merge)
from repro.distributed.plan import ClientShardingPlan


def shard_cohort_train(mesh, train_fn: Callable, *,
                       replicated: int = 0) -> Callable:
    """Wrap a pure stacked-train function in a client-sharded runner.

    ``train_fn(*args)`` must treat its leading client axis elementwise
    (the engine contract: vmap over clients of a scan over local
    steps).  The first ``replicated`` positional args are broadcast to
    every device (the shared global params of the sync path); every
    remaining arg is a stacked pytree/array whose leading axis is
    sharded over the mesh's client axis.  Cohorts are padded to a
    multiple of the mesh size by repeating the last real row
    (deterministic duplicate work, sliced off again — real rows are
    unaffected because the axis is elementwise), so uneven cohorts and
    cohorts smaller than the mesh both work.

    The returned runner jits one shard_map program per argument arity;
    padded cohort shapes bound retraces exactly like the engine's pow2
    convention.
    """
    axis = mesh.axis_names[0]
    jitted: Dict[int, Callable] = {}

    def _build(nargs: int):
        in_specs = tuple([P()] * replicated
                         + [P(axis)] * (nargs - replicated))
        return jax.jit(shard_map(train_fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=P(axis), check_rep=False))

    def run(*args):
        sharded_args = args[replicated:]
        if not sharded_args:
            raise ValueError("shard_cohort_train needs at least one "
                             "sharded (per-client) argument")
        n = jax.tree_util.tree_leaves(sharded_args[0])[0].shape[0]
        plan = ClientShardingPlan.for_cohort(n, mesh)
        padded = tuple(plan.pad_stacked(a, mode="edge")
                       for a in sharded_args)
        fn = jitted.get(len(args))
        if fn is None:
            fn = jitted[len(args)] = _build(len(args))
        return plan.unpad(fn(*args[:replicated], *padded))

    return run


class ShardedClientEngine(BatchedClientEngine):
    """``BatchedClientEngine`` whose cohorts run under ``shard_map``
    over a 1-D client mesh and whose merges are sharded psum
    reductions.  One instance per (run, mesh)."""

    def __init__(self, trainer, mesh, *, interpret: Optional[bool] = None,
                 pad_cohorts: bool = True, **kw):
        super().__init__(trainer, interpret=interpret,
                         pad_cohorts=pad_cohorts, **kw)
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"client mesh must be 1-D, got axes {mesh.axis_names}")
        self.mesh = mesh
        self._wrapped: Dict[tuple, Callable] = {}
        self._accepts_wrap: Dict[str, bool] = {}

    # -- cohort padding: compose pow2 with the mesh multiple ------------
    def _pad_target(self, n: int) -> int:
        # lists padded here land on a multiple of the mesh size already,
        # so the per-bucket edge padding inside shard_cohort_train is a
        # no-op whenever the cohort is a single shape bucket.
        return ClientShardingPlan.for_cohort(n, self.mesh,
                                             pow2=True).padded_n

    # -- trainer hook ---------------------------------------------------
    def _wrap(self, train_fn: Callable, replicated: int) -> Callable:
        """The ``wrap`` hook handed to trainers: cache one sharded
        runner per (function, replicated-arity)."""
        key = (getattr(train_fn, "__func__", train_fn), int(replicated))
        fn = self._wrapped.get(key)
        if fn is None:
            fn = shard_cohort_train(self.mesh, train_fn,
                                    replicated=replicated)
            self._wrapped[key] = fn
        return fn

    def _trainer_takes_wrap(self, name: str) -> bool:
        ok = self._accepts_wrap.get(name)
        if ok is None:
            try:
                params = inspect.signature(
                    getattr(self.trainer, name)).parameters
                ok = "wrap" in params
            except (TypeError, ValueError):
                ok = False
            self._accepts_wrap[name] = ok
        return ok

    def _local_train_batch(self, params, ids, rnd_seed):
        if self._trainer_takes_wrap("local_train_batch"):
            return self.trainer.local_train_batch(params, ids, rnd_seed,
                                                  wrap=self._wrap)
        return super()._local_train_batch(params, ids, rnd_seed)

    def _local_train_cohort(self, stacked_starts, ids, seeds):
        if self._trainer_takes_wrap("local_train_cohort"):
            return self.trainer.local_train_cohort(stacked_starts, ids,
                                                   seeds, wrap=self._wrap)
        return super()._local_train_cohort(stacked_starts, ids, seeds)

    # -- aggregation: per-shard partial sums + one psum -----------------
    def aggregate(self, stacked, weights):
        return sharded_aggregate(self.mesh, stacked, weights,
                                 use_kernel=self.use_kernel_agg,
                                 interpret=self.interpret)

    def aggregate_or_keep(self, params, stacked, weights):
        # the all-masked guard rides the psum'd denominator: a
        # device-side select, no host sync (mirrors the base engine's
        # lax.cond guard).
        return sharded_aggregate(self.mesh, stacked, weights,
                                 fallback=params,
                                 use_kernel=self.use_kernel_agg,
                                 interpret=self.interpret)

    def merge_staleness(self, params, stacked, alphas):
        return sharded_staleness_merge(self.mesh, params, stacked, alphas,
                                       use_kernel=self.use_kernel_agg,
                                       interpret=self.interpret)
