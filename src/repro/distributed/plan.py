"""Cohort padding plan for the client mesh axis.

``shard_map`` requires every sharded leading axis to divide evenly by
the mesh size, but FL cohorts are whatever the scheduler drained: N not
divisible by the mesh, N smaller than the mesh, ragged shape buckets.
``ClientShardingPlan`` owns the arithmetic, reusing the engine's two
padding conventions so padded rows are exact no-ops:

* **training** pads by repeating the last real row (``mode="edge"``,
  the engine's pow2-padding convention): duplicate rows do duplicate,
  deterministic work and are sliced off by ``unpad`` — real rows are
  untouched because the client axis is elementwise-parallel;
* **aggregation** pads with zero rows *and* zero weights/alphas
  (``mode="zero"`` + ``pad_weights``): the fused straggler masking in
  ``weighted_average_stacked`` / the fedagg kernel / the sharded psum
  reduction zeroes any row with effective weight <= 0, so padded rows
  contribute exactly nothing to the merge.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Union

import jax
import jax.numpy as jnp

from repro.distributed.mesh import CLIENT_AXIS


@dataclass(frozen=True)
class ClientShardingPlan:
    """How a cohort of ``n`` client rows lands on a ``mesh_size``-way
    client mesh: padded to ``padded_n`` (a multiple of the mesh size,
    >= the mesh size)."""

    n: int
    mesh_size: int
    padded_n: int
    axis: str = CLIENT_AXIS

    @classmethod
    def for_cohort(cls, n: int, mesh: Union[int, "jax.sharding.Mesh"], *,
                   pow2: bool = False) -> "ClientShardingPlan":
        """Plan for ``n`` rows over ``mesh`` (a Mesh or a raw size).

        ``pow2=True`` first rounds ``n`` up to the next power of two —
        the engine's retrace-bounding convention — then up to a
        multiple of the mesh size (for the usual power-of-two device
        counts the second step is free once n >= mesh).
        """
        if isinstance(mesh, int):
            d, axis = mesh, CLIENT_AXIS
        else:
            d, axis = int(mesh.size), mesh.axis_names[0]
        if n < 1:
            raise ValueError(f"cohort must have at least one row, got {n}")
        if d < 1:
            raise ValueError(f"mesh must have at least one device, got {d}")
        m = int(n)
        if pow2:
            m = 1 << (m - 1).bit_length()
        m = -(-m // d) * d
        return cls(n=int(n), mesh_size=d, padded_n=m, axis=axis)

    @property
    def pad_rows(self) -> int:
        return self.padded_n - self.n

    def pad_stacked(self, tree, *, mode: str = "edge"):
        """Pad every leaf's leading (client) axis up to ``padded_n``.

        ``mode="edge"`` repeats the last real row (training path);
        ``mode="zero"`` appends zero rows (aggregation path — pair with
        ``pad_weights`` so the mask makes them exact no-ops).
        """
        if mode not in ("edge", "zero"):
            raise ValueError(f"unknown pad mode {mode!r}")
        if not self.pad_rows:
            return tree

        def pad_leaf(leaf):
            leaf = jnp.asarray(leaf)
            if mode == "edge":
                fill = jnp.broadcast_to(leaf[-1:],
                                        (self.pad_rows,) + leaf.shape[1:])
            else:
                fill = jnp.zeros((self.pad_rows,) + leaf.shape[1:],
                                 leaf.dtype)
            return jnp.concatenate([leaf, fill], axis=0)

        return jax.tree_util.tree_map(pad_leaf, tree)

    def pad_weights(self, vec):
        """Zero-fill an (N,) weight/alpha vector to ``padded_n`` — the
        zero-alpha masking convention: a padded row's effective weight
        is 0, so the merge treats it exactly like a masked straggler."""
        vec = jnp.asarray(vec, jnp.float32).reshape(-1)
        if not self.pad_rows:
            return vec
        return jnp.concatenate(
            [vec, jnp.zeros((self.pad_rows,), jnp.float32)])

    def unpad(self, tree):
        """Slice every leaf back to the real ``n`` rows."""
        if not self.pad_rows:
            return tree
        return jax.tree_util.tree_map(lambda l: l[: self.n], tree)
