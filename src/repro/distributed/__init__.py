"""Client-sharded distributed execution subsystem.

Shards FL cohorts over a 1-D ``("clients",)`` device mesh:
``make_client_mesh`` builds the mesh, ``ClientShardingPlan`` pads
cohorts to mesh multiples with exact-no-op rows, ``shard_cohort_train``
runs local epochs under ``shard_map`` with zero cross-device traffic,
and ``sharded_aggregate`` / ``sharded_staleness_merge`` reduce
per-shard partial sums into one psum.  ``ShardedClientEngine`` packages
it all behind the ``BatchedClientEngine`` interface; schedulers select
it via ``make_engine(..., mesh=...)``.

Lazy exports: ``hostdevices`` (env plumbing, importable before jax
backend init) loads eagerly; everything touching jax loads on first
attribute access so entry points can still order ``XLA_FLAGS`` setup
before device initialization.
"""

from repro.distributed.hostdevices import (ensure_host_device_count,
                                           forced_host_device_count)

_LAZY = {
    "CLIENT_AXIS": "mesh",
    "make_client_mesh": "mesh",
    "ClientShardingPlan": "plan",
    "sharded_aggregate": "aggregate",
    "sharded_staleness_merge": "aggregate",
    "ShardedClientEngine": "engine",
    "shard_cohort_train": "engine",
}

__all__ = ["ensure_host_device_count", "forced_host_device_count",
           *sorted(_LAZY)]


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f"{__name__}.{mod}"), name)


def __dir__():
    return sorted(set(globals()) | set(__all__))
