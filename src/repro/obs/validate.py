"""Validate a JSONL telemetry trace against the export schema.

    PYTHONPATH=src python -m repro.obs.validate trace.jsonl

Exit 0 when the file is a well-formed trace (meta header first, every
line a known record type with its required keys); exit 2 with a
per-line diagnostic otherwise.  CI runs this on the traced
``fl_train`` smoke before uploading the trace artifact.
"""

from __future__ import annotations

import json
import sys
from typing import List, Tuple

from repro.obs.export import JSONL_TYPES
from repro.obs.telemetry import SCHEMA_VERSION

REQUIRED = {
    "meta": ("schema_version", "clock"),
    "span": ("name", "ts_us", "dur_us", "vt0", "vt1", "args"),
    "counter": ("name", "value"),
    "gauge": ("name", "last", "series"),
    "hist": ("name", "count", "mean", "p50", "p95", "max"),
    "summary": ("wall_s", "spans", "counters"),
}


def validate_lines(lines) -> Tuple[List[str], dict]:
    """-> (errors, counts-by-type); empty errors == valid trace."""
    errors: List[str] = []
    counts = {t: 0 for t in JSONL_TYPES}
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"line {i}: not JSON ({e})")
            continue
        t = rec.get("type")
        if t not in JSONL_TYPES:
            errors.append(f"line {i}: unknown record type {t!r}")
            continue
        counts[t] += 1
        missing = [k for k in REQUIRED[t] if k not in rec]
        if missing:
            errors.append(f"line {i}: {t} record missing {missing}")
        if t == "meta":
            if i != 1:
                errors.append(f"line {i}: meta header must be line 1")
            elif rec.get("schema_version") != SCHEMA_VERSION:
                errors.append(
                    f"line 1: schema_version "
                    f"{rec.get('schema_version')!r} != {SCHEMA_VERSION}")
    if counts["meta"] != 1:
        errors.append(f"expected exactly 1 meta header, got "
                      f"{counts['meta']}")
    if counts["summary"] != 1:
        errors.append(f"expected exactly 1 summary record, got "
                      f"{counts['summary']}")
    if counts["span"] == 0:
        errors.append("trace contains no spans")
    return errors, counts


def validate_file(path: str) -> Tuple[List[str], dict]:
    with open(path) as f:
        return validate_lines(f)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print("usage: python -m repro.obs.validate TRACE.jsonl",
              file=sys.stderr)
        return 2
    errors, counts = validate_file(argv[0])
    if errors:
        for e in errors:
            print(f"[validate] {e}", file=sys.stderr)
        print(f"[validate] {argv[0]}: INVALID ({len(errors)} error(s))",
              file=sys.stderr)
        return 2
    print(f"[validate] {argv[0]}: OK  "
          + "  ".join(f"{t}={n}" for t, n in counts.items() if n))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
