"""Validate an exported telemetry trace against its schema.

    PYTHONPATH=src python -m repro.obs.validate trace.jsonl
    PYTHONPATH=src python -m repro.obs.validate --format chrome trace.json

``--format`` is ``jsonl`` (line-delimited event log), ``chrome``
(trace_event JSON as written by ``export_chrome``), or ``auto`` (the
default: a file whose first byte opens a JSON object containing
``traceEvents`` is chrome, else JSONL).  Exit 0 when the file is a
well-formed trace; exit 2 with diagnostics otherwise.  CI runs this on
BOTH formats of the traced ``fl_train`` smoke before uploading the
trace artifacts.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Tuple

from repro.obs.export import JSONL_TYPES
from repro.obs.telemetry import SCHEMA_VERSION

REQUIRED = {
    "meta": ("schema_version", "clock"),
    "span": ("name", "ts_us", "dur_us", "vt0", "vt1", "args"),
    "counter": ("name", "value"),
    "gauge": ("name", "last", "series"),
    "hist": ("name", "count", "mean", "p50", "p95", "max"),
    "summary": ("wall_s", "spans", "counters"),
}


def validate_lines(lines) -> Tuple[List[str], dict]:
    """-> (errors, counts-by-type); empty errors == valid trace."""
    errors: List[str] = []
    counts = {t: 0 for t in JSONL_TYPES}
    for i, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"line {i}: not JSON ({e})")
            continue
        t = rec.get("type")
        if t not in JSONL_TYPES:
            errors.append(f"line {i}: unknown record type {t!r}")
            continue
        counts[t] += 1
        missing = [k for k in REQUIRED[t] if k not in rec]
        if missing:
            errors.append(f"line {i}: {t} record missing {missing}")
        if t == "meta":
            if i != 1:
                errors.append(f"line {i}: meta header must be line 1")
            elif rec.get("schema_version") != SCHEMA_VERSION:
                errors.append(
                    f"line 1: schema_version "
                    f"{rec.get('schema_version')!r} != {SCHEMA_VERSION}")
    if counts["meta"] != 1:
        errors.append(f"expected exactly 1 meta header, got "
                      f"{counts['meta']}")
    if counts["summary"] != 1:
        errors.append(f"expected exactly 1 summary record, got "
                      f"{counts['summary']}")
    if counts["span"] == 0:
        errors.append("trace contains no spans")
    return errors, counts


def validate_file(path: str) -> Tuple[List[str], dict]:
    with open(path) as f:
        return validate_lines(f)


# ---------------------------------------------------------------------------
# chrome trace_event format (export_chrome)
# ---------------------------------------------------------------------------

# required keys per chrome event phase we emit ("M" metadata, "X"
# complete span, "C" counter track)
CHROME_PHASES = {
    "M": ("name", "pid", "tid", "args"),
    "X": ("name", "ph", "pid", "tid", "ts", "dur", "args"),
    "C": ("name", "ph", "pid", "tid", "ts", "args"),
}


def validate_chrome(doc) -> Tuple[List[str], dict]:
    """-> (errors, counts-by-phase); empty errors == valid trace."""
    errors: List[str] = []
    counts = {ph: 0 for ph in CHROME_PHASES}
    if not isinstance(doc, dict):
        return [f"top level must be a JSON object, got "
                f"{type(doc).__name__}"], counts
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        errors.append("missing traceEvents list")
        events = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph", "M")
        if ph not in CHROME_PHASES:
            errors.append(f"event {i}: unknown phase {ev.get('ph')!r}")
            continue
        counts[ph] += 1
        missing = [k for k in CHROME_PHASES[ph] if k not in ev]
        if missing:
            errors.append(f"event {i}: {ph} event missing {missing}")
            continue
        if ph == "X":
            args = ev["args"]
            if not isinstance(args, dict) \
                    or "vt0" not in args or "vt1" not in args:
                errors.append(f"event {i}: X event args must carry the "
                              f"virtual-time interval (vt0/vt1)")
    other = doc.get("otherData")
    if not isinstance(other, dict):
        errors.append("missing otherData object")
    else:
        if other.get("schema_version") != SCHEMA_VERSION:
            errors.append(f"otherData.schema_version "
                          f"{other.get('schema_version')!r} "
                          f"!= {SCHEMA_VERSION}")
        if not isinstance(other.get("counters"), dict):
            errors.append("otherData.counters must be an object")
        summary = other.get("summary")
        if not isinstance(summary, dict):
            errors.append("missing otherData.summary object")
        else:
            missing = [k for k in REQUIRED["summary"] if k not in summary]
            if missing:
                errors.append(f"otherData.summary missing {missing}")
    if counts["X"] == 0:
        errors.append("trace contains no spans (X events)")
    return errors, counts


def validate_chrome_file(path: str) -> Tuple[List[str], dict]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except json.JSONDecodeError as e:
        return [f"not a JSON document ({e})"], {}
    return validate_chrome(doc)


def sniff_format(path: str) -> str:
    """"chrome" when the file is one JSON object with ``traceEvents``,
    else "jsonl"."""
    with open(path) as f:
        head = f.read(4096)
    if head.lstrip().startswith("{"):
        try:
            first = json.loads(head.splitlines()[0])
            if isinstance(first, dict) and first.get("type") in JSONL_TYPES:
                return "jsonl"
        except json.JSONDecodeError:
            pass
        return "chrome"
    return "jsonl"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.validate",
        description="Validate an exported telemetry trace (JSONL event "
                    "log or Chrome trace_event JSON).")
    ap.add_argument("path", help="trace file to validate")
    ap.add_argument("--format", default="auto",
                    choices=["auto", "jsonl", "chrome"],
                    help="trace format (auto = sniff: a JSON object "
                         "with traceEvents is chrome, else jsonl)")
    args = ap.parse_args(argv)
    fmt = args.format
    try:
        if fmt == "auto":
            fmt = sniff_format(args.path)
        if fmt == "chrome":
            errors, counts = validate_chrome_file(args.path)
        else:
            errors, counts = validate_file(args.path)
    except OSError as e:
        print(f"[validate] cannot read {args.path}: {e}", file=sys.stderr)
        return 2
    if errors:
        for e in errors:
            print(f"[validate] {e}", file=sys.stderr)
        print(f"[validate] {args.path} ({fmt}): INVALID "
              f"({len(errors)} error(s))", file=sys.stderr)
        return 2
    print(f"[validate] {args.path} ({fmt}): OK  "
          + "  ".join(f"{t}={n}" for t, n in counts.items() if n))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
