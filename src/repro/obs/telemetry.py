"""Runtime telemetry: span tracer + metrics registry.

FedDCT's claims are about *time* — where a round's wall-clock actually
goes (queue wait vs gather vs cohort train vs merge vs scatter vs
eviction) is the datum every perf PR needs and ``RunHistory`` cannot
carry.  This module is the zero-overhead-when-disabled core:

* ``TEL`` is the module-global active telemetry.  It defaults to the
  ``NOOP`` singleton, whose every method is a constant-return no-op —
  an instrumented call site (``obs.TEL.span(...)``) pays one module
  attribute lookup plus one trivial method call when tracing is off,
  and the no-op ``span`` hands back a shared null context manager (no
  allocation).  ``enable()`` swaps in a recording ``Telemetry``;
  ``disable()`` swaps ``NOOP`` back and returns the recording for
  export.
* ``Telemetry.span(name, **args)`` records BOTH clocks: host
  wall-clock (``perf_counter``) and the simulated virtual time the
  runners maintain via ``set_virtual_time`` — so a trace can show that
  a merge which took 2 ms of host time covered 40 virtual seconds of
  simulated network wait.
* counters / gauges / histograms (``inc`` / ``gauge`` / ``observe``)
  feed the end-of-run aggregate (``summary`` /
  ``summarize_into(hist.meta)`` — the ``meta["telemetry"]`` block).
* jitted-program recompiles are counted for free through
  ``jax.monitoring``: the first ``enable()`` registers listeners that
  increment ``jax.compiles`` (and observe ``jax.compile_s``) on every
  backend compile.  The listeners check ``TEL.enabled`` and stay inert
  when tracing is off.

Clock caveat: JAX dispatch is asynchronous, so a span around a jitted
call measures host-side dispatch plus whatever the wrapped code blocks
on; device time is absorbed by the next blocking point (``evaluate``,
``np.asarray``, ``block_until_ready``).  Spans attribute where the
HOST spends its time — which is exactly the server-step overhead the
store/runtime PRs optimize.

Exporters (JSONL event log, Chrome ``trace_event`` for
chrome://tracing / Perfetto) live in ``repro.obs.export``.
"""

from __future__ import annotations

import contextlib
from time import perf_counter
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

# hard caps so a runaway loop cannot swallow host memory; overflow is
# counted (``telemetry.dropped_*``), never silent
MAX_SPANS = 500_000
MAX_SERIES = 100_000
MAX_HIST = 500_000


class _NoopSpan:
    """Shared null span: context manager AND manual start/end, every
    method a no-op returning ``self`` so call sites never branch."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def start(self):
        return self

    def end(self):
        return self

    def set(self, **args):
        return self


_NOOP_SPAN = _NoopSpan()


class NoopTelemetry:
    """The disabled-mode singleton: every hook is a constant no-op."""

    __slots__ = ()
    enabled = False

    def span(self, name, **args):
        return _NOOP_SPAN

    def inc(self, name, n=1):
        pass

    def gauge(self, name, value):
        pass

    def observe(self, name, value):
        pass

    def set_virtual_time(self, t):
        pass

    def summarize_into(self, meta):
        pass


NOOP = NoopTelemetry()

# the active telemetry — instrumented modules read ``obs.TEL`` fresh on
# every use (one attribute lookup), so enable/disable swaps take effect
# everywhere at once
TEL = NOOP


class Span:
    """One traced section: wall-clock + virtual-time interval with
    attached args.  Works as a context manager or via explicit
    ``start()`` / ``end()`` (for loops that cannot re-indent)."""

    __slots__ = ("_tel", "name", "args", "t0", "vt0")

    def __init__(self, tel: "Telemetry", name: str, args: Dict):
        self._tel = tel
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.vt0 = 0.0

    def set(self, **args):
        self.args.update(args)
        return self

    def start(self):
        self.t0 = perf_counter()
        self.vt0 = self._tel.vt
        return self

    def end(self):
        self._tel._record_span(self)
        return self

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.end()
        return False


class Telemetry:
    """Recording telemetry: spans + counters + gauges + histograms."""

    enabled = True

    def __init__(self):
        self.t0 = perf_counter()     # trace epoch (host clock origin)
        self.vt = 0.0                # current simulated virtual time
        self.spans: List[Dict] = []
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.gauge_series: Dict[str, List] = {}
        self.hists: Dict[str, List[float]] = {}

    # -- spans ----------------------------------------------------------
    def span(self, name: str, **args) -> Span:
        return Span(self, name, args)

    def _record_span(self, s: Span):
        if len(self.spans) >= MAX_SPANS:
            self.inc("telemetry.dropped_spans")
            return
        now = perf_counter()
        self.spans.append({
            "name": s.name,
            "ts_us": (s.t0 - self.t0) * 1e6,
            "dur_us": (now - s.t0) * 1e6,
            "vt0": s.vt0,
            "vt1": self.vt,
            "args": s.args,
        })

    # -- virtual clock --------------------------------------------------
    def set_virtual_time(self, t: float):
        self.vt = float(t)

    # -- metrics --------------------------------------------------------
    def inc(self, name: str, n=1):
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value):
        value = float(value)
        self.gauges[name] = value
        series = self.gauge_series.setdefault(name, [])
        if len(series) < MAX_SERIES:
            series.append(((perf_counter() - self.t0) * 1e6, value))
        else:
            self.inc("telemetry.dropped_gauge_points")

    def observe(self, name: str, value):
        vals = self.hists.setdefault(name, [])
        if len(vals) < MAX_HIST:
            vals.append(float(value))
        else:
            self.inc("telemetry.dropped_hist_points")

    # -- aggregate summary ----------------------------------------------
    def summary(self) -> Dict:
        """End-of-run aggregate: per-span totals, counters, last gauge
        values, histogram stats, and derived rates (prefetch hit rate,
        lookahead accuracy) when their counters exist."""
        spans: Dict[str, Dict] = {}
        for s in self.spans:
            agg = spans.setdefault(s["name"], {"count": 0, "total_s": 0.0,
                                               "total_vt": 0.0})
            agg["count"] += 1
            agg["total_s"] += s["dur_us"] / 1e6
            agg["total_vt"] += s["vt1"] - s["vt0"]
        for agg in spans.values():
            agg["mean_s"] = agg["total_s"] / agg["count"]
        hists = {}
        for name, vals in self.hists.items():
            import numpy as np
            a = np.asarray(vals, np.float64)
            hists[name] = {"count": int(a.size), "mean": float(a.mean()),
                           "p50": float(np.percentile(a, 50)),
                           "p95": float(np.percentile(a, 95)),
                           "max": float(a.max())}
        out = {"wall_s": perf_counter() - self.t0,
               "spans": spans,
               "counters": dict(self.counters),
               "gauges": dict(self.gauges),
               "hists": hists}
        rates = {}
        c = self.counters
        hit = c.get("residency.demand_hit", 0)
        miss = c.get("residency.demand_promote", 0)
        if hit + miss:
            rates["prefetch_hit_rate"] = hit / (hit + miss)
        la_hit = c.get("lookahead.hit", 0)
        la_miss = c.get("lookahead.miss", 0)
        if la_hit + la_miss:
            rates["lookahead_accuracy"] = la_hit / (la_hit + la_miss)
        if rates:
            out["rates"] = rates
        return out

    def summarize_into(self, meta: Dict):
        """Fold the aggregate into a ``RunHistory.meta`` dict (the
        ``meta["telemetry"]`` block every traced run carries)."""
        meta["telemetry"] = self.summary()

    # -- export convenience (see repro.obs.export) ----------------------
    def export_jsonl(self, path: str) -> str:
        from repro.obs.export import export_jsonl
        return export_jsonl(self, path)

    def export_chrome(self, path: str) -> str:
        from repro.obs.export import export_chrome
        return export_chrome(self, path)


# -- enable / disable ----------------------------------------------------

_jax_hooked = False


def _hook_jax_monitoring():
    """Count jitted-program recompiles through ``jax.monitoring``.

    Registered once per process (listeners cannot be unregistered
    individually without clobbering other callers'); the callbacks read
    the CURRENT ``TEL`` and are inert when tracing is off."""
    global _jax_hooked
    if _jax_hooked:
        return
    try:
        from jax import monitoring
    except ImportError:                                    # pragma: no cover
        return

    def _on_duration(event, duration, **kw):
        t = TEL
        if t.enabled and event.endswith("backend_compile_duration"):
            t.inc("jax.compiles")
            t.observe("jax.compile_s", duration)

    def _on_event(event, **kw):
        t = TEL
        if t.enabled and "compilation_cache" in event:
            t.inc("jax.cache." + event.rsplit("/", 1)[-1])

    monitoring.register_event_duration_secs_listener(_on_duration)
    monitoring.register_event_listener(_on_event)
    _jax_hooked = True


def enable(tel: Optional[Telemetry] = None) -> Telemetry:
    """Install a recording telemetry as the process-wide ``TEL``."""
    global TEL
    _hook_jax_monitoring()
    TEL = tel if tel is not None else Telemetry()
    return TEL


def disable() -> "Telemetry | NoopTelemetry":
    """Swap ``NOOP`` back in; returns the telemetry that was active
    (export it, then drop it)."""
    global TEL
    t = TEL
    TEL = NOOP
    return t


@contextlib.contextmanager
def tracing(tel: Optional[Telemetry] = None):
    """``with tracing() as tel:`` — enable for the block, always
    restore ``NOOP`` after."""
    t = enable(tel)
    try:
        yield t
    finally:
        disable()
