"""Runtime telemetry layer: spans, counters, structured trace export.

Instrumented modules import the submodule and read the active
telemetry fresh on every use (zero-overhead-when-disabled contract —
one attribute lookup on the no-op singleton):

    from repro.obs import telemetry as obs
    with obs.TEL.span("window.gather", rows=n):
        ...
    obs.TEL.inc("residency.demand_promote", k)

Users enable tracing around a run and export afterwards:

    from repro import obs
    with obs.tracing() as tel:
        hist = run_method(...)          # meta["telemetry"] is folded in
    tel.export_chrome("trace.json")     # chrome://tracing / Perfetto
    tel.export_jsonl("trace.jsonl")     # repro.obs.validate checks this

or from the CLI: ``fl_train.py --trace PATH [--trace-format
jsonl|chrome]``.

FL-semantic labeled streams (per-tier / per-client diagnostics) live in
``repro.obs.flstats``; ``repro.obs.report`` folds a trace or a
``RunHistory`` JSON into the paper-Table-2-style per-tier report
(``python -m repro.obs.report``).
"""

from repro.obs.telemetry import (NOOP, SCHEMA_VERSION, NoopTelemetry,
                                 Telemetry, disable, enable, tracing)

__all__ = ["NOOP", "SCHEMA_VERSION", "NoopTelemetry", "Telemetry",
           "disable", "enable", "tracing"]
