"""Fold a trace into the paper-Table-2-style per-tier run report.

The FedDCT evaluation tables slice a run by tier: how many clients
each tier contributed, how often a tier hit its timeout threshold,
how close responses ran to the assigned ``D_max``, and how the global
accuracy / virtual-time trajectory paid for those choices.  This
module rebuilds that view from any of the three places a traced run
lands its aggregate:

* a JSONL trace (``fl_train.py --trace run.jsonl``) — the trailing
  ``summary`` line;
* a Chrome trace (``--trace-format chrome``) —
  ``otherData.summary``;
* a saved ``RunHistory`` JSON (``--out hist.json``) —
  ``meta["telemetry"]`` (this source also carries the
  accuracy/virtual-time trajectory).

CLI::

    PYTHONPATH=src python -m repro.obs.report run.jsonl
    PYTHONPATH=src python -m repro.obs.report hist.json --json report.json

or in-process via ``fl_train.py --report [PATH]``.  Output is the text
table plus (optionally) the structured JSON report; exit status 2 when
the input carries no telemetry summary.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Optional, Tuple

from repro.obs.flstats import parse_label


# ---------------------------------------------------------------------------
# loading: trace file / history file -> (summary dict, history dict|None)
# ---------------------------------------------------------------------------

def load_source(path: str) -> Tuple[Optional[Dict], Optional[Dict]]:
    """-> ``(telemetry_summary, run_history_dict)``; either may be
    ``None``.  Sniffs the three formats by shape, not extension."""
    with open(path) as f:
        first = f.readline()
        rest = f.read()
    try:
        head = json.loads(first)
    except json.JSONDecodeError:
        # a multi-line JSON document (chrome trace / pretty history)
        head = None
    if isinstance(head, dict) and head.get("type") == "meta" and rest:
        # JSONL trace: the summary is the trailing line
        summary = None
        for line in rest.splitlines():
            if not line.strip():
                continue
            rec = json.loads(line)
            if rec.get("type") == "summary":
                summary = {k: v for k, v in rec.items() if k != "type"}
        return summary, None
    doc = json.loads(first + rest)
    if "traceEvents" in doc:                       # chrome trace
        return doc.get("otherData", {}).get("summary"), None
    if "meta" in doc or "method" in doc:           # RunHistory JSON
        return doc.get("meta", {}).get("telemetry"), doc
    if "counters" in doc and "hists" in doc:       # bare summary dict
        return doc, None
    return None, None


# ---------------------------------------------------------------------------
# report construction
# ---------------------------------------------------------------------------

def _labeled(table: Dict, base: str, key: str = "tier") -> Dict[int, object]:
    """All ``base{key=v}`` entries of a counters/gauges/hists table,
    keyed by the int label value."""
    out = {}
    for name, value in table.items():
        b, labels = parse_label(name)
        if b == base and key in labels:
            out[int(labels[key])] = value
    return out


def build_report(summary: Dict, history: Optional[Dict] = None) -> Dict:
    """Fold one telemetry summary (+ optional ``RunHistory`` dict) into
    the structured per-tier report."""
    counters = summary.get("counters", {})
    gauges = summary.get("gauges", {})
    hists = summary.get("hists", {})

    selected = _labeled(counters, "fl.tier.selected")
    participated = _labeled(counters, "fl.tier.participate")
    timeouts = _labeled(counters, "fl.tier.timeout")
    carried = _labeled(counters, "fl.straggler.carried")
    dropped = _labeled(counters, "fl.straggler.dropped")
    sizes = _labeled(gauges, "fl.tier.size")
    thr_gauge = _labeled(gauges, "fl.tier.threshold_s")
    resp = _labeled(hists, "fl.response_s")
    frac = _labeled(hists, "fl.response_frac")
    thr = _labeled(hists, "fl.threshold_s")
    stale = _labeled(hists, "fl.staleness")
    uplink = _labeled(counters, "fl.bytes.up")
    n_rounds = int(counters.get("fl.tier.rounds", 0))

    tier_ids = sorted(set(selected) | set(participated) | set(timeouts)
                      | set(sizes) | set(resp) | set(uplink))
    tiers = {}
    for t in tier_ids:
        part = int(participated.get(t, 0))
        hits = int(timeouts.get(t, 0))
        seen = part + hits
        row = {
            "selected": int(selected.get(t, 0)),
            "participated": part,
            "timeout_hits": hits,
            "timeout_hit_rate": (hits / seen) if seen else 0.0,
            "carried": int(carried.get(t, 0)),
            "dropped": int(dropped.get(t, 0)),
        }
        if t in sizes:
            row["size_last"] = int(sizes[t])
        r = resp.get(t)
        if r:
            row["mean_response_s"] = r["mean"]
            row["p95_response_s"] = r["p95"]
        d = thr.get(t)
        if d:
            row["mean_threshold_s"] = d["mean"]
        elif t in thr_gauge:
            row["mean_threshold_s"] = thr_gauge[t]
        fr = frac.get(t)
        if fr:
            row["mean_response_frac"] = fr["mean"]
        st = stale.get(t)
        if st:
            row["staleness_mean"] = st["mean"]
            row["staleness_p95"] = st["p95"]
        # communication volume (PR 9 ``fl.bytes.up{tier=}`` counters);
        # traces from older runs simply have no entry -> "-" columns
        if t in uplink:
            b = int(uplink[t])
            row["uplink_bytes"] = b
            row["uplink_mb"] = b / 1e6
            if n_rounds:
                row["uplink_bytes_per_round"] = b / n_rounds
        tiers[t] = row

    migrations = {}
    for name, n in counters.items():
        base, labels = parse_label(name)
        if base == "fl.tier.migration":
            migrations[f"{labels['from']}->{labels['to']}"] = int(n)

    population = int(gauges.get("fl.population", 0))
    sel_counts = {c: n for c, n in
                  _labeled(counters, "fl.client.selected", "client").items()}
    upd_counts = {c: n for c, n in
                  _labeled(counters, "fl.client.update", "client").items()}
    fairness = {}
    if sel_counts or population:
        from repro.core.selection import participation_fairness
        fairness["selection"] = participation_fairness(sel_counts,
                                                       population)
        if upd_counts:
            fairness["updates"] = participation_fairness(upd_counts,
                                                         population)

    report = {
        "rounds": int(counters.get("fl.tier.rounds", 0)),
        "population": population,
        "tiers": tiers,
        "migration_matrix": migrations,
        "n_migrations": sum(migrations.values()),
        "fairness": fairness,
        "stragglers": {
            "carried": int(sum(carried.values())
                           + counters.get("fl.straggler.carried", 0)),
            "dropped": int(sum(dropped.values())
                           + counters.get("fl.straggler.dropped", 0)),
        },
        "dropped_labels": int(counters.get("telemetry.dropped_fl_labels",
                                           0)),
        "wall_s": summary.get("wall_s"),
    }
    total_up = int(sum(uplink.values())
                   + counters.get("fl.bytes.up", 0))
    if total_up:
        report["uplink"] = {
            "total_bytes": total_up,
            "total_mb": total_up / 1e6,
            "bytes_per_round": (total_up / n_rounds) if n_rounds else None,
        }
    norm = hists.get("fl.cohort.update_norm")
    if norm:
        report["cohort_update_norm"] = norm
    if history is not None:
        acc = history.get("accuracy") or []
        times = history.get("times") or []
        report["trajectory"] = {
            "method": history.get("method"),
            "evals": len(acc),
            "final_accuracy": acc[-1] if acc else None,
            "best_accuracy": max(acc) if acc else None,
            "final_virtual_s": times[-1] if times else None,
            "times": times,
            "accuracy": acc,
        }
    return report


# ---------------------------------------------------------------------------
# text rendering
# ---------------------------------------------------------------------------

def _fmt(v, spec=".3f") -> str:
    return "-" if v is None else format(v, spec)


def format_report(report: Dict, source: str = "") -> str:
    lines = []
    head = f"== FL run report{f' ({source})' if source else ''} =="
    lines.append(head)
    lines.append(f"rounds={report['rounds']} "
                 f"population={report['population']} "
                 f"migrations={report['n_migrations']} "
                 f"stragglers: carried={report['stragglers']['carried']} "
                 f"dropped={report['stragglers']['dropped']}")
    cols = ["tier", "size", "selected", "particip", "timeouts", "hit_rate",
            "resp_s", "thr_s", "headroom", "stale_p95", "up_B/rnd",
            "up_MB"]
    rows = [cols]
    for t, r in sorted(report["tiers"].items()):
        rows.append([
            str(t), _fmt(r.get("size_last"), "d"),
            str(r["selected"]), str(r["participated"]),
            str(r["timeout_hits"]), _fmt(r["timeout_hit_rate"], ".2f"),
            _fmt(r.get("mean_response_s")), _fmt(r.get("mean_threshold_s")),
            _fmt(r.get("mean_response_frac"), ".2f"),
            _fmt(r.get("staleness_p95"), ".1f"),
            _fmt(r.get("uplink_bytes_per_round"), ".0f"),
            _fmt(r.get("uplink_mb"), ".3f"),
        ])
    widths = [max(len(row[i]) for row in rows) for i in range(len(cols))]
    for row in rows:
        lines.append("  ".join(c.rjust(w) for c, w in zip(row, widths)))
    if report["migration_matrix"]:
        pairs = ", ".join(f"{k}: {v}" for k, v in
                          sorted(report["migration_matrix"].items()))
        lines.append(f"migration matrix  {pairs}")
    up = report.get("uplink")
    if up:
        per_rnd = (f" ({up['bytes_per_round']:.0f} B/round)"
                   if up.get("bytes_per_round") else "")
        lines.append(f"uplink  {up['total_mb']:.3f} MB modeled"
                     f"{per_rnd}")
    sel = report["fairness"].get("selection")
    if sel:
        lines.append(f"selection fairness  gini={sel['gini']:.3f} "
                     f"coverage={sel['coverage']:.2f} "
                     f"min={sel['min']:.0f} max={sel['max']:.0f}")
    traj = report.get("trajectory")
    if traj and traj["evals"]:
        lines.append(f"trajectory  {traj['method']}: "
                     f"final acc={traj['final_accuracy']:.4f} "
                     f"(best {traj['best_accuracy']:.4f}) "
                     f"@ virtual {traj['final_virtual_s']:.1f}s "
                     f"over {traj['evals']} evals")
    if report["dropped_labels"]:
        lines.append(f"WARNING: {report['dropped_labels']} labeled "
                     f"records dropped at the cardinality cap")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Per-tier FL run report from a trace (jsonl/chrome) "
                    "or a saved RunHistory JSON.")
    ap.add_argument("path", help="trace file or RunHistory JSON")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the structured report as JSON here")
    args = ap.parse_args(argv)
    try:
        summary, history = load_source(args.path)
    except (OSError, json.JSONDecodeError) as e:
        print(f"report: cannot read {args.path}: {e}", file=sys.stderr)
        return 2
    if summary is None:
        print(f"report: no telemetry summary in {args.path} "
              f"(traced run required)", file=sys.stderr)
        return 2
    report = build_report(summary, history)
    print(format_report(report, source=args.path))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"report: json -> {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
