"""FL-semantic labeled metric streams over the generic telemetry.

PR 7's spans/counters answer "where did the host's time go"; this layer
answers the questions the paper's evaluation actually asks — which tier
did a client sit in at round R, how often did tiers migrate, how close
did each tier run to its timeout threshold, who got starved by
selection, and how stale were the merged updates.  Every record lands
in the SAME ``Telemetry`` registries (counters / gauges / histograms)
under a labeled name, so the existing exporters, the validator, the
``meta["telemetry"]`` fold and the phase blocks in ``BENCH_*.json``
carry the FL view for free; ``repro.obs.report`` folds it into the
paper-Table-2-style per-tier report.

Label encoding: ``base{k=v,k2=v2}`` with sorted keys — flat strings,
so the registries stay plain dicts.  ``parse_label`` inverts it.

Contract (same as the rest of ``repro.obs``):

* zero overhead when disabled — every ``record_*`` first reads
  ``obs.TEL`` and returns before ANY formatting or math when tracing
  is off (call sites that would build an argument list guard on
  ``TEL.enabled`` themselves);
* numerically invisible when enabled — records only ever READ run
  state (the one device computation, the cohort update norm, is a pure
  reduction of values the run already produced);
* hard cardinality caps — labeled streams are LOW-cardinality by
  construction (tiers, tier pairs); the one per-client stream is
  capped at ``MAX_CLIENT_LABELS`` distinct clients and overflow is
  counted as ``telemetry.dropped_fl_labels``, never silent.

Catalogue (all tier labels are 1-indexed):

==========================================  ===============================
counter ``fl.tier.selected{tier=}``          selections per tier
counter ``fl.tier.participate{tier=}``       made the tier threshold/window
counter ``fl.tier.timeout{tier=}``           hit the tier timeout
counter ``fl.tier.migration{from=,to=}``     round-indexed migration matrix
counter ``fl.tier.rounds``                   tiering invocations
counter ``fl.straggler.carried{tier=}``      async: merged late, not lost
counter ``fl.straggler.dropped{tier=}``      sync: update discarded
counter ``fl.client.selected{client=}``      per-client selection counts
counter ``fl.client.update{client=}``        per-client merged updates
counter ``fl.bytes.up``(+``{tier=}``)        modeled uplink bytes (wire
                                             format: int8+meta or f32)
gauge   ``fl.population``                    total client count
gauge   ``fl.tier.count``                    number of tiers this round
gauge   ``fl.tier.size{tier=}``              membership time series
gauge   ``fl.tier.threshold_s{tier=}``       per-round threshold series
hist    ``fl.response_s{tier=}``             response-time distribution
hist    ``fl.response_frac{tier=}``          response / threshold headroom
hist    ``fl.threshold_s{tier=}``            threshold distribution
hist    ``fl.staleness``(+``{tier=}``)       merged-update staleness
hist    ``fl.cohort.update_norm``            per-cohort update L2 norm
==========================================  ===============================
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence, Tuple

from repro.obs import telemetry as obs

# distinct label strings allowed per base metric name; the per-client
# streams get a wider budget (they are the one intentionally-per-entity
# series), everything else is tier-shaped and tiny.
MAX_LABELS_PER_METRIC = 64
MAX_CLIENT_LABELS = 4096
_CLIENT_METRICS = ("fl.client.selected", "fl.client.update")

DROPPED = "telemetry.dropped_fl_labels"


def label(base: str, **labels) -> str:
    """``label("fl.tier.size", tier=2) -> "fl.tier.size{tier=2}"``."""
    if not labels:
        return base
    body = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{base}{{{body}}}"


def parse_label(name: str) -> Tuple[str, Dict[str, str]]:
    """Inverse of ``label`` (labels come back as strings)."""
    if not name.endswith("}") or "{" not in name:
        return name, {}
    base, _, body = name.partition("{")
    out = {}
    for kv in body[:-1].split(","):
        k, _, v = kv.partition("=")
        out[k] = v
    return base, out


def _admit(tel, base: str, name: str) -> bool:
    """Cardinality gate: may ``name`` (one label string of ``base``) be
    recorded?  Admitted names are remembered on the recording
    ``Telemetry`` instance (every ``tracing()`` block starts fresh);
    an over-cap name is counted as ``telemetry.dropped_fl_labels``."""
    seen = getattr(tel, "_fl_label_sets", None)
    if seen is None:
        seen = tel._fl_label_sets = {}
    names = seen.setdefault(base, set())
    if name in names:
        return True
    cap = (MAX_CLIENT_LABELS if base in _CLIENT_METRICS
           else MAX_LABELS_PER_METRIC)
    if len(names) >= cap:
        tel.inc(DROPPED)
        return False
    names.add(name)
    return True


def _inc(tel, base: str, n=1, **labels):
    name = label(base, **labels)
    if _admit(tel, base, name):
        tel.inc(name, n)


def _observe(tel, base: str, value, **labels):
    name = label(base, **labels)
    if _admit(tel, base, name):
        tel.observe(name, value)


def _gauge(tel, base: str, value, **labels):
    name = label(base, **labels)
    if _admit(tel, base, name):
        tel.gauge(name, value)


# ---------------------------------------------------------------------------
# recording hooks (each early-returns when tracing is off)
# ---------------------------------------------------------------------------

def record_tiering(tiers, thresholds: Optional[Sequence[float]] = None,
                   population: int = 0):
    """One round's (re-)tiering: membership sizes, the round-indexed
    migration matrix (diffed against the last round on a per-run
    ``TierMigrationTracker``), and the per-tier timeout-threshold
    series when the caller knows it."""
    tel = obs.TEL
    if not tel.enabled:
        return
    from repro.core.tiering import TierMigrationTracker
    tracker = getattr(tel, "_fl_tier_tracker", None)
    if tracker is None:
        tracker = tel._fl_tier_tracker = TierMigrationTracker()
    moves = tracker.update(tiers)
    for (t_old, t_new), n in moves.items():
        _inc(tel, "fl.tier.migration", n, **{"from": t_old, "to": t_new})
    tel.inc("fl.tier.rounds")
    tel.gauge("fl.tier.count", len(tiers))
    if population:
        tel.gauge("fl.population", population)
    for k, members in enumerate(tiers):
        _gauge(tel, "fl.tier.size", len(members), tier=k + 1)
    if thresholds is not None:
        for k, d in enumerate(thresholds):
            _gauge(tel, "fl.tier.threshold_s", d, tier=k + 1)
            _observe(tel, "fl.threshold_s", float(d), tier=k + 1)


def record_selection(selected, population: int = 0):
    """One round's selection.  ``selected`` is either plain client ids
    or the CSTT ``(client, tier_idx0)`` pairs; pairs also feed the
    per-tier selection counters."""
    tel = obs.TEL
    if not tel.enabled:
        return
    if population:
        tel.gauge("fl.population", population)
    for item in selected:
        if isinstance(item, tuple):
            c, k = item
            _inc(tel, "fl.tier.selected", tier=k + 1)
        else:
            c = item
        _inc(tel, "fl.client.selected", client=int(c))


def record_response(tier: int, response_s: float, threshold_s: float,
                    timed_out: bool):
    """One selected client's response time against its tier's assigned
    timeout threshold (``tier`` is 1-indexed)."""
    tel = obs.TEL
    if not tel.enabled:
        return
    _observe(tel, "fl.response_s", float(response_s), tier=tier)
    if threshold_s > 0:
        _observe(tel, "fl.response_frac",
                 float(response_s) / float(threshold_s), tier=tier)
    _inc(tel, "fl.tier.timeout" if timed_out else "fl.tier.participate",
         tier=tier)


def record_staleness(stalenesses: Iterable[int],
                     tiers: Optional[Iterable[Optional[int]]] = None):
    """Staleness of one merged window's rows; ``tiers`` (1-indexed, or
    ``None`` per row) adds the per-tier histograms when the runner
    knows which tier each completion was selected from."""
    tel = obs.TEL
    if not tel.enabled:
        return
    tiers = list(tiers) if tiers is not None else None
    for i, s in enumerate(stalenesses):
        tel.observe("fl.staleness", float(s))
        t = tiers[i] if tiers is not None else None
        if t is not None:
            _observe(tel, "fl.staleness", float(s), tier=t)


def record_straggler(kind: str, tier: Optional[int] = None, n: int = 1):
    """``kind`` "carried" (async: merged after its round) or "dropped"
    (sync: update discarded at the tier timeout)."""
    tel = obs.TEL
    if not tel.enabled:
        return
    if tier is None:
        tel.inc(f"fl.straggler.{kind}", n)
    else:
        _inc(tel, f"fl.straggler.{kind}", n, tier=tier)


def record_uplink(nbytes: int, tier: Optional[int] = None):
    """Modeled uplink bytes of merged client updates — ``nbytes`` is
    the wire size of the updates that landed this window (row format
    dependent: int8+meta under ``quant_bits=8``, full f32 otherwise).
    Labeled per 1-indexed tier when the runner knows it (feddct_async);
    the plain counter otherwise (fedasync/fedbuff)."""
    tel = obs.TEL
    if not tel.enabled or nbytes <= 0:
        return
    if tier is None:
        tel.inc("fl.bytes.up", int(nbytes))
    else:
        _inc(tel, "fl.bytes.up", int(nbytes), tier=tier)


def record_client_updates(client_ids: Iterable[int]):
    """Clients whose update actually merged this window (the async
    runners' participation stream)."""
    tel = obs.TEL
    if not tel.enabled:
        return
    for c in client_ids:
        _inc(tel, "fl.client.update", client=int(c))


def record_update_norm(stacked, n_rows: int):
    """L2 norm of one drained cohort's stacked update rows (the first
    ``n_rows`` — the rest are pad duplicates).  Pure read of values the
    run already produced; the device sync it forces only exists while
    tracing."""
    tel = obs.TEL
    if not tel.enabled or stacked is None or n_rows <= 0:
        return
    import jax
    import jax.numpy as jnp
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(stacked):
        rows = leaf[:n_rows].astype(jnp.float32)
        total += float(jnp.sum(rows * rows))
    tel.observe("fl.cohort.update_norm", total ** 0.5)
