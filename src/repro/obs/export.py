"""Structured trace exporters: JSONL event log + Chrome trace_event.

* ``export_jsonl`` writes one JSON object per line — a ``meta`` header
  (schema version, clock convention, jax context) followed by every
  span, counter, gauge series, histogram summary, and the end-of-run
  aggregate.  ``repro.obs.validate`` checks this schema (CI gates the
  traced ``fl_train`` smoke on it).
* ``export_chrome`` writes the Chrome ``trace_event`` JSON format:
  open it at chrome://tracing or https://ui.perfetto.dev.  Spans are
  complete ("X") events on one pid/tid (the runtime is single-
  threaded); each span carries its virtual-time interval in ``args``;
  gauge series (queue depth, …) become counter ("C") tracks.

Timestamps are microseconds since the telemetry's ``perf_counter``
epoch — relative host wall-clock, not civil time.
"""

from __future__ import annotations

import json
import os
from typing import Dict

from repro.obs.telemetry import SCHEMA_VERSION, Telemetry

JSONL_TYPES = ("meta", "span", "counter", "gauge", "hist", "summary")


def _meta_header(tel: Telemetry) -> Dict:
    ctx = {"type": "meta", "schema_version": SCHEMA_VERSION,
           "clock": "perf_counter_us", "virtual_clock": "seconds"}
    try:
        import jax
        ctx["jax"] = jax.__version__
        ctx["backend"] = jax.default_backend()
        ctx["device_count"] = jax.device_count()
    except (ImportError, AttributeError, RuntimeError):    # pragma: no cover
        pass
    return ctx


def export_jsonl(tel: Telemetry, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    summary = tel.summary()
    with open(path, "w") as f:
        f.write(json.dumps(_meta_header(tel)) + "\n")
        for s in tel.spans:
            f.write(json.dumps({"type": "span", **s}) + "\n")
        for name, value in sorted(tel.counters.items()):
            f.write(json.dumps({"type": "counter", "name": name,
                                "value": value}) + "\n")
        for name, series in sorted(tel.gauge_series.items()):
            f.write(json.dumps({"type": "gauge", "name": name,
                                "last": tel.gauges[name],
                                "series": series}) + "\n")
        for name, stats in sorted(summary["hists"].items()):
            f.write(json.dumps({"type": "hist", "name": name,
                                **stats}) + "\n")
        f.write(json.dumps({"type": "summary", **summary}) + "\n")
    return path


def export_chrome(tel: Telemetry, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    events = [
        {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "repro telemetry"}},
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0,
         "args": {"name": "runtime"}},
    ]
    for s in tel.spans:
        events.append({
            "name": s["name"], "cat": s["name"].split(".")[0],
            "ph": "X", "pid": 0, "tid": 0,
            "ts": s["ts_us"], "dur": s["dur_us"],
            "args": {**s["args"], "vt0": s["vt0"], "vt1": s["vt1"]},
        })
    for name, series in sorted(tel.gauge_series.items()):
        for ts, value in series:
            events.append({"name": name, "ph": "C", "pid": 0, "tid": 0,
                           "ts": ts, "args": {name: value}})
    payload = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"schema_version": SCHEMA_VERSION,
                      "counters": tel.counters,
                      "summary": tel.summary()},
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    return path
