"""The documented telemetry name catalogue.

Every literal span/counter/gauge/histogram name recorded through
``obs.TEL`` must appear here — ``fedlint``'s FED004 rule machine-checks
call sites against these sets, so a typo'd or undocumented stream
cannot silently land in traces (the ROADMAP catalogue prose and this
module must move together; ``tests/test_fedlint.py`` cross-checks a
recorded run against it at runtime too).

Labeled FL-semantic streams (``repro.obs.flstats``) record under
``base{k=v,...}`` names: the *base* is catalogued here, the label part
is stripped before the check (``flstats.parse_label`` inverts it).
Dynamic families that cannot be enumerated (``telemetry.dropped_*``
overflow counters, ``jax.cache.*`` compilation-cache events) are
admitted by prefix.
"""

from __future__ import annotations

#: span names (see ROADMAP "Telemetry" for who records each)
SPANS = frozenset({
    "run",
    "round.select", "round.train", "round.aggregate",
    "window.stage", "window.gather", "window.train",
    "window.merge_scatter",
    "window.prefetch", "window.merge", "window.reschedule",
    "store.merge", "store.scatter",
    "residency.promote", "residency.write_behind",
    "residency.host_gather",
    "eval",
})

#: counters — plain runtime counters plus the flstats labeled BASES
COUNTERS = frozenset({
    "residency.demand_hit", "residency.demand_promote",
    "residency.prefetch_hit", "residency.prefetch_promote",
    "residency.write_behind", "residency.evict_clean",
    "residency.write_around", "residency.oversubscribed_gather",
    "lookahead.hit", "lookahead.miss",
    "drain.count", "drain.deadline", "drain.budget", "drain.sequential",
    "drain.queue_drained", "drain.queue_empty",
    "stragglers.carried", "stragglers.dropped",
    "store.donation_active", "store.donation_skipped",
    "jax.compiles",
    # flstats labeled bases (tier/client labels stripped before check)
    "fl.tier.selected", "fl.tier.participate", "fl.tier.timeout",
    "fl.tier.migration", "fl.tier.rounds",
    "fl.straggler.carried", "fl.straggler.dropped",
    "fl.client.selected", "fl.client.update",
    "fl.bytes.up",
})

#: open-ended counter families admitted by prefix
COUNTER_PREFIXES = ("telemetry.dropped_", "jax.cache.")

GAUGES = frozenset({
    "queue.depth", "queue.inflight",
    "store.bytes_hot", "store.bytes_cold",
    "fl.population", "fl.tier.count", "fl.tier.size",
    "fl.tier.threshold_s",
})

HISTS = frozenset({
    "cohort.size", "jax.compile_s",
    "fl.response_s", "fl.response_frac", "fl.threshold_s",
    "fl.staleness", "fl.cohort.update_norm",
})

ALL = SPANS | COUNTERS | GAUGES | HISTS


def kind_of(name: str) -> str:
    """Catalogue kind of a recorded name ("span"/"counter"/"gauge"/
    "hist"), or "unknown".  Labels (``base{k=v}``) are stripped."""
    base = name.split("{", 1)[0]
    if base in SPANS:
        return "span"
    if base in COUNTERS or base.startswith(COUNTER_PREFIXES):
        return "counter"
    if base in GAUGES:
        return "gauge"
    if base in HISTS:
        return "hist"
    return "unknown"
