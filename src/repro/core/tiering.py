"""Dynamic tiering (paper Alg. 3 + Eqs. 1-2).

``tiering`` re-runs every round on the *current* running-average training
times — this is what makes FedDCT's tiers dynamic, vs TiFL's frozen
profiling-time tiers.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def tiering(at: Dict[int, float], m: int) -> List[List[int]]:
    """Alg. 3: sort clients by average time ascending, split into tiers of
    width ``m`` (tier 1 fastest).  Returns list of tiers (client-id lists).

    ``at`` holds only *currently tierable* clients (stragglers under
    re-evaluation are absent, exactly like Alg. 2's flow).
    """
    if not at:
        return []
    order = sorted(at, key=lambda c: (at[c], c))
    m = max(int(m), 1)
    return [order[i:i + m] for i in range(0, len(order), m)]


def assignment(tiers: List[List[int]]) -> Dict[int, int]:
    """client -> 1-indexed tier number for one ``tiering`` output."""
    return {c: k + 1 for k, members in enumerate(tiers) for c in members}


class TierMigrationTracker:
    """Round-indexed tier-migration accounting for DYNAMIC tiering.

    Feed it every round's ``tiering`` output; it diffs each client's
    tier against the last round the client was tierable and counts the
    moves.  Clients absent from a round (in flight, or in the straggler
    re-evaluation lane) keep their last known tier, so a client that
    returns to the same tier is NOT a migration — only genuine
    reassignments count, which is exactly the "how often did tiers
    migrate" datum TiFL-style evaluations tabulate.
    """

    def __init__(self):
        self.prev: Dict[int, int] = {}            # client -> last tier
        self.matrix: Dict[Tuple[int, int], int] = {}
        self.rounds = 0

    def update(self, tiers: List[List[int]]) -> Dict[Tuple[int, int], int]:
        """Record one round's assignment; -> this round's migrations
        ``{(from_tier, to_tier): count}`` (new clients are not moves)."""
        cur = assignment(tiers)
        moves: Dict[Tuple[int, int], int] = {}
        for c, t_new in cur.items():
            t_old = self.prev.get(c)
            if t_old is not None and t_old != t_new:
                moves[(t_old, t_new)] = moves.get((t_old, t_new), 0) + 1
        for key, n in moves.items():
            self.matrix[key] = self.matrix.get(key, 0) + n
        self.prev.update(cur)
        self.rounds += 1
        return moves

    def n_migrations(self) -> int:
        return sum(self.matrix.values())


def update_avg_time(at: float, ct: int, t_train: float) -> float:
    """Eq. 2: running average over successful rounds."""
    return (at * ct + t_train) / (ct + 1)


def evaluate_client(network, client: int, rnd: int, kappa: int,
                    omega: float) -> tuple[float, float]:
    """Profile a client with kappa evaluation rounds (Alg. 2 init and the
    straggler re-evaluation lane).  Attempts are capped at omega each (a
    dead client costs at most kappa*omega and simply re-enters the lane).

    Returns (new_average_time, wall_time_spent).
    """
    k = max(kappa, 1)
    times = network.delays([client] * k, rnd, attempt=np.arange(k) + 1)
    return float(np.mean(times)), float(np.minimum(times, omega).sum())
