"""Dynamic tiering (paper Alg. 3 + Eqs. 1-2).

``tiering`` re-runs every round on the *current* running-average training
times — this is what makes FedDCT's tiers dynamic, vs TiFL's frozen
profiling-time tiers.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def tiering(at: Dict[int, float], m: int) -> List[List[int]]:
    """Alg. 3: sort clients by average time ascending, split into tiers of
    width ``m`` (tier 1 fastest).  Returns list of tiers (client-id lists).

    ``at`` holds only *currently tierable* clients (stragglers under
    re-evaluation are absent, exactly like Alg. 2's flow).
    """
    if not at:
        return []
    order = sorted(at, key=lambda c: (at[c], c))
    m = max(int(m), 1)
    return [order[i:i + m] for i in range(0, len(order), m)]


def update_avg_time(at: float, ct: int, t_train: float) -> float:
    """Eq. 2: running average over successful rounds."""
    return (at * ct + t_train) / (ct + 1)


def evaluate_client(network, client: int, rnd: int, kappa: int,
                    omega: float) -> tuple[float, float]:
    """Profile a client with kappa evaluation rounds (Alg. 2 init and the
    straggler re-evaluation lane).  Attempts are capped at omega each (a
    dead client costs at most kappa*omega and simply re-enters the lane).

    Returns (new_average_time, wall_time_spent).
    """
    k = max(kappa, 1)
    times = network.delays([client] * k, rnd, attempt=np.arange(k) + 1)
    return float(np.mean(times)), float(np.minimum(times, omega).sum())
