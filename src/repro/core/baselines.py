"""Baseline FL methods the paper compares against (§5.1).

* FedAvg  [McMahan'17]: random tau clients per round; server waits for
  every selected client (failures hurt: round = max client time).
* TiFL    [Chai'20]: one-off profiling -> STATIC tiers; clients whose
  profiled time >= Omega are dropped for good; credit + accuracy based
  adaptive tier selection; round capped at Omega (slower uploads lost).
* FedAsync [Xie'19]: fully asynchronous, staleness-weighted merge
  alpha_t = alpha * (t - tau_i + 1)^(-a); event-queue virtual clock.
  Runs on the event-driven runtime (repro.runtime) — ``window=0`` is
  the classic one-merge-per-event loop, ``window``/``window_secs``
  batch concurrently-finishing completions into one vmapped cohort.
* FedBuff [Nguyen'22]: FedAsync with a K-completion aggregation goal —
  the runtime with a count window.
* FedProx [Li'20]: FedAvg + proximal blend toward the global model
  (extra baseline beyond the paper).

All methods share the trainer + WirelessNetwork realization with FedDCT
and run their per-round cohort through the batched execution engine
(core/engine.py) — one vmapped device program per round instead of a
per-client Python loop (pass ``engine="looped"`` for the reference
path).  Sync rounds keep the all-masked guard on device
(``engine.train_round``'s ``lax.cond``); async methods keep client
snapshots in the device-resident ``ClientStateStore`` (one flat (N, P)
buffer, ``use_store=False`` for the dict-of-pytrees reference).
"""

from __future__ import annotations

import heapq
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import FLConfig
from repro.core.aggregation import staleness_merge
from repro.core.engine import make_engine
from repro.core.tiering import evaluate_client, tiering
from repro.fl.metrics import RunHistory
from repro.obs import flstats
from repro.obs import telemetry as obs


def _mesh_devices(mesh) -> int:
    """Uniform ``meta["mesh_devices"]`` value across every loop (the
    async runners record the same key), so tooling never has to branch
    on the method to learn the execution width."""
    return int(mesh.size) if mesh is not None else 1


def run_fedavg(trainer, network, fl: FLConfig, *, use_kernel_agg: bool = False,
               engine: str = "batched", verbose: bool = False,
               eval_every: int = 1, mesh=None) -> RunHistory:
    rng = np.random.default_rng(fl.seed + 11)
    tel = obs.TEL
    run_span = tel.span("run", method="fedavg").start()
    hist = RunHistory(method="fedavg", arch=trainer.cfg.arch_id,
                      meta={"mu": fl.mu, "primary_frac": fl.primary_frac,
                            "engine": engine,
                            "kernel_agg": use_kernel_agg,
                            "mesh_devices": _mesh_devices(mesh)})
    eng = make_engine(trainer, use_kernel_agg=use_kernel_agg, engine=engine,
                      mesh=mesh)
    params = trainer.init_params(fl.seed)
    clock = 0.0
    for rnd in range(1, fl.rounds + 1):
        tel.set_virtual_time(clock)
        sel = [int(c) for c in rng.choice(fl.n_clients,
                                          size=min(fl.tau, fl.n_clients),
                                          replace=False)]
        flstats.record_selection(sel, population=fl.n_clients)
        times = network.delays(sel, rnd)
        params = eng.train_round(params, sel, rnd)
        clock += float(times.max())              # waits for everyone
        if rnd % eval_every == 0:
            with tel.span("eval"):
                acc = trainer.evaluate(params)
            hist.record(time=clock, rnd=rnd, acc=acc,
                        n_selected=len(sel))
            if verbose:
                print(f"[fedavg] r={rnd:4d} t={clock:9.1f}s acc={acc:.4f}")
            if fl.target_accuracy and acc >= fl.target_accuracy:
                break
    run_span.end()
    tel.summarize_into(hist.meta)
    return hist


def run_tifl(trainer, network, fl: FLConfig, *, use_kernel_agg: bool = False,
             engine: str = "batched", verbose: bool = False,
             eval_every: int = 1, mesh=None) -> RunHistory:
    rng = np.random.default_rng(fl.seed + 13)
    tel = obs.TEL
    run_span = tel.span("run", method="tifl").start()
    hist = RunHistory(method="tifl", arch=trainer.cfg.arch_id,
                      meta={"mu": fl.mu, "primary_frac": fl.primary_frac,
                            "engine": engine,
                            "kernel_agg": use_kernel_agg,
                            "mesh_devices": _mesh_devices(mesh)})
    eng = make_engine(trainer, use_kernel_agg=use_kernel_agg, engine=engine,
                      mesh=mesh)
    params = trainer.init_params(fl.seed)
    clock = 0.0

    # one-off profiling (static tiers; >=Omega dropped permanently — the
    # behaviour the paper criticises when mu>0 mis-classifies clients)
    at: Dict[int, float] = {}
    spent_all = []
    for c in range(fl.n_clients):
        t_avg, spent = evaluate_client(network, c, rnd=0, kappa=fl.kappa,
                                       omega=fl.omega)
        spent_all.append(spent)
        if t_avg < fl.omega:
            at[c] = t_avg
    clock += max(spent_all)
    m = max(fl.n_clients // fl.n_tiers, 1)
    tiers = tiering(at, m)
    # TiFL's tiers are STATIC — recorded once, so the migration matrix
    # of a TiFL trace is empty by construction (the FedDCT contrast).
    flstats.record_tiering(tiers, population=fl.n_clients)
    n_tiers = len(tiers)
    credits = [fl.rounds // max(n_tiers, 1) + 1] * n_tiers
    tier_acc = [0.0] * n_tiers
    probs = np.ones(n_tiers) / max(n_tiers, 1)

    for rnd in range(1, fl.rounds + 1):
        tel.set_virtual_time(clock)
        live = [k for k in range(n_tiers) if credits[k] > 0 and tiers[k]]
        if not live:
            live = [k for k in range(n_tiers) if tiers[k]]
        p = np.array([probs[k] for k in live], np.float64)
        p = p / p.sum() if p.sum() > 0 else np.ones(len(live)) / len(live)
        k = int(rng.choice(live, p=p))
        credits[k] -= 1
        members = tiers[k]
        sel = [int(c) for c in rng.choice(members,
                                          size=min(fl.tau, len(members)),
                                          replace=False)]
        flstats.record_selection([(c, k) for c in sel],
                                 population=fl.n_clients)
        times, survivors = [], []
        for c, st in zip(sel, network.delays(sel, rnd)):
            times.append(min(st, fl.omega))
            flstats.record_response(k + 1, float(st), fl.omega,
                                    timed_out=st >= fl.omega)
            if st >= fl.omega:               # lost this round
                flstats.record_straggler("dropped", tier=k + 1)
                continue
            survivors.append(c)
        params = eng.train_round(params, survivors, rnd)
        clock += max(times) if times else 0.0
        if rnd % eval_every == 0:
            with tel.span("eval"):
                acc = trainer.evaluate(params)
        else:
            acc = None
        if acc is not None:
            tier_acc[k] = acc
            # adaptive: favour tiers with lower observed accuracy (TiFL §4)
            inv = np.array([1.0 - a for a in tier_acc], np.float64)
            probs = inv / inv.sum() if inv.sum() > 0 else probs
            hist.record(time=clock, rnd=rnd, acc=acc, tier=k + 1,
                        n_selected=len(sel),
                        n_stragglers=len(sel) - len(survivors))
            if verbose:
                print(f"[tifl]   r={rnd:4d} t={clock:9.1f}s tier={k+1} "
                      f"acc={acc:.4f}")
            if fl.target_accuracy and acc >= fl.target_accuracy:
                break
    run_span.end()
    tel.summarize_into(hist.meta)
    return hist


def run_fedasync_sequential(trainer, network, fl: FLConfig, *,
                            engine: str = "batched", verbose: bool = False,
                            eval_every: int = 5) -> RunHistory:
    """The pre-runtime sequential FedAsync loop: one merge per event.

    Kept as the reference implementation the event-driven runtime is
    equivalence-tested against (``run_fedasync(window=0)`` must produce
    an identical ``RunHistory``).  New callers should use
    ``run_fedasync``.
    """
    hist = RunHistory(method="fedasync", arch=trainer.cfg.arch_id,
                      meta={"mu": fl.mu, "primary_frac": fl.primary_frac,
                            "alpha": fl.async_alpha, "a": fl.async_a})
    eng = make_engine(trainer, engine=engine)
    params = trainer.init_params(fl.seed)
    clock = 0.0
    version = 0
    # true async: each client trains from the global model snapshot taken
    # when it STARTED (not finished) — that is what staleness weights fix.
    snapshot: Dict[int, object] = {c: params for c in range(fl.n_clients)}
    # event queue: (finish_time, client, model_version_at_start, round_idx)
    heap: List = []
    for t, c in zip(network.delays(np.arange(fl.n_clients), 0),
                    range(fl.n_clients)):
        heapq.heappush(heap, (float(t), c, 0, 0))
    # budget: same number of aggregations as sync methods have rounds*tau
    max_updates = fl.rounds * fl.tau
    upd = 0
    for upd in range(1, max_updates + 1):
        finish, c, v0, ridx = heapq.heappop(heap)
        clock = finish
        # events are inherently sequential (each merge precedes the next
        # event), so the engine runs a cohort of one — still the shared
        # jitted scan path, just not vmapped across clients.
        stacked, _ = eng.train_clients(snapshot[c], [c], ridx * 977 + c)
        new_p = jax.tree_util.tree_map(lambda l: l[0], stacked)
        staleness = version - v0
        if fl.async_staleness == "poly":
            alpha_t = fl.async_alpha * (staleness + 1.0) ** (-fl.async_a)
        else:
            alpha_t = fl.async_alpha
        params = staleness_merge(params, new_p, alpha_t)
        version += 1
        snapshot[c] = params
        heapq.heappush(heap, (clock + network.delay(c, ridx + 1), c,
                              version, ridx + 1))
        if upd % eval_every == 0:
            acc = trainer.evaluate(params)
            hist.record(time=clock, rnd=upd, acc=acc, n_selected=1)
            if verbose:
                print(f"[fedasync] u={upd:5d} t={clock:9.1f}s acc={acc:.4f}")
            if fl.target_accuracy and acc >= fl.target_accuracy:
                break
    # terminal eval: the budget can run out between eval points — record
    # the true final state so RunHistory ends where the model ends.
    if not hist.rounds or hist.rounds[-1] != upd:
        hist.record(time=clock, rnd=upd, acc=trainer.evaluate(params),
                    n_selected=1)
    return hist


def run_fedasync(trainer, network, fl: FLConfig, *, engine: str = "batched",
                 use_kernel_agg: bool = False, verbose: bool = False,
                 eval_every: int = 5, window: int = 0,
                 window_secs: float = 0.0, mesh=None,
                 use_store=None, store_capacity=None,
                 store_cold_dir=None, quant_bits: int = 32,
                 error_feedback: bool = True) -> RunHistory:
    """FedAsync on the event-driven runtime.

    ``window=0`` (default) reproduces the sequential one-merge-per-event
    loop history-identically; ``window=K`` / ``window_secs=T`` batch
    concurrently-finishing completions into one vmapped cohort merged
    with per-client staleness weights (FedBuff / time-triggered
    semantics).  Windowed runs keep snapshots in the device-resident
    ``ClientStateStore`` by default; ``use_store`` is tri-state (None =
    auto: store exactly when windows batch, False = dict-of-pytrees
    reference path — histories bit-identical either way).
    ``store_capacity`` caps the hot device rows (tiered residency with
    EventQueue-driven prefetch; ``store_cold_dir`` spills the cold tier
    to disk) — histories stay bit-identical at any capacity.
    """
    from repro.runtime.async_loop import AsyncRunner
    return AsyncRunner(trainer, network, fl, method="fedasync",
                       engine=engine, use_kernel_agg=use_kernel_agg,
                       window=window, window_secs=window_secs,
                       eval_every=eval_every, verbose=verbose,
                       mesh=mesh, use_store=use_store,
                       store_capacity=store_capacity,
                       store_cold_dir=store_cold_dir,
                       quant_bits=quant_bits,
                       error_feedback=error_feedback).run()


def run_fedbuff(trainer, network, fl: FLConfig, *, engine: str = "batched",
                use_kernel_agg: bool = False, verbose: bool = False,
                eval_every: int = 5, window: int = 0,
                window_secs: float = 0.0, mesh=None,
                use_store=None, store_capacity=None,
                store_cold_dir=None, quant_bits: int = 32,
                error_feedback: bool = True) -> RunHistory:
    """FedBuff [Nguyen'22]: async with a K-completion aggregation goal
    (default K = fl.tau, the sync methods' per-round cohort size)."""
    from repro.runtime.async_loop import AsyncRunner
    return AsyncRunner(trainer, network, fl, method="fedbuff",
                       engine=engine, use_kernel_agg=use_kernel_agg,
                       window=window or fl.tau, window_secs=window_secs,
                       eval_every=eval_every, verbose=verbose,
                       mesh=mesh, use_store=use_store,
                       store_capacity=store_capacity,
                       store_cold_dir=store_cold_dir,
                       quant_bits=quant_bits,
                       error_feedback=error_feedback).run()


def run_feddct_async(trainer, network, fl: FLConfig, **kw) -> RunHistory:
    """Semi-async FedDCT (tier timeouts as aggregation windows); see
    repro.runtime.async_loop.run_feddct_async."""
    from repro.runtime.async_loop import run_feddct_async as _run
    return _run(trainer, network, fl, **kw)


def run_method(method: str, trainer, network, fl: FLConfig, **kw
               ) -> RunHistory:
    from repro.core.scheduler import run_feddct
    fns = {"feddct": run_feddct, "fedavg": run_fedavg, "tifl": run_tifl,
           "fedasync": run_fedasync, "fedprox": run_fedprox,
           "fedbuff": run_fedbuff, "feddct_async": run_feddct_async}
    return fns[method](trainer, network, fl, **kw)


def run_fedprox(trainer, network, fl: FLConfig, *, prox_mu: float = 0.01,
                use_kernel_agg: bool = False, engine: str = "batched",
                verbose: bool = False, eval_every: int = 1,
                mesh=None) -> RunHistory:
    """FedProx [Li et al. 2020]: FedAvg + proximal term pulling local
    models toward the global model (extra baseline beyond the paper).

    Implemented generically: after local training, each update is blended
    toward the global params by 1/(1+prox_mu_eff) — the closed form of
    the proximal step for quadratic regularization applied post-hoc,
    which keeps the trainer interface unchanged.  The blend runs on the
    STACKED cohort (broadcast over the client axis), so the whole round
    stays a device program.
    """
    rng = np.random.default_rng(fl.seed + 17)
    tel = obs.TEL
    run_span = tel.span("run", method="fedprox").start()
    hist = RunHistory(method="fedprox", arch=trainer.cfg.arch_id,
                      meta={"mu": fl.mu, "prox_mu": prox_mu,
                            "engine": engine,
                            "kernel_agg": use_kernel_agg,
                            "mesh_devices": _mesh_devices(mesh)})
    eng = make_engine(trainer, use_kernel_agg=use_kernel_agg, engine=engine,
                      mesh=mesh)
    params = trainer.init_params(fl.seed)
    clock = 0.0
    blend = 1.0 / (1.0 + prox_mu * 10)
    for rnd in range(1, fl.rounds + 1):
        tel.set_virtual_time(clock)
        sel = [int(c) for c in rng.choice(fl.n_clients,
                                          size=min(fl.tau, fl.n_clients),
                                          replace=False)]
        flstats.record_selection(sel, population=fl.n_clients)
        times = network.delays(sel, rnd)
        with tel.span("round.train", cohort=len(sel)):
            stacked, sizes = eng.train_clients(params, sel, rnd)
        with tel.span("round.aggregate", cohort=len(sel)):
            prox = jax.tree_util.tree_map(
                lambda n, g: (blend * n.astype(jnp.float32)
                              + (1 - blend) * g.astype(jnp.float32)[None]
                              ).astype(n.dtype), stacked, params)
            params = eng.aggregate(prox, sizes)
        clock += float(times.max())
        if rnd % eval_every == 0:
            with tel.span("eval"):
                acc = trainer.evaluate(params)
            hist.record(time=clock, rnd=rnd, acc=acc, n_selected=len(sel))
            if verbose:
                print(f"[fedprox] r={rnd:4d} t={clock:9.1f}s acc={acc:.4f}")
            if fl.target_accuracy and acc >= fl.target_accuracy:
                break
    run_span.end()
    tel.summarize_into(hist.meta)
    return hist
