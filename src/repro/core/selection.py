"""Cross-tier client selection and per-tier timeout thresholds
(paper Alg. 4 "CSTT" + Eqs. 3, 4, 7).

Fidelity note (DESIGN.md §7.1): Eq. 4's written form conflicts with the
text's stated intent; we follow the text and Alg. 4's "select the lowest
tau clients": within each tier, the tau clients with the *fewest*
successful rounds (lowest ct) win, ties broken by a seeded shuffle.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def move_tier(t: int, v_now: float, v_prev: float, n_tiers: int) -> int:
    """Eq. 3: accuracy improved -> faster tier (t-1); regressed -> t+1."""
    if v_now >= v_prev:
        return max(t - 1, 1)
    return min(t + 1, n_tiers)


def select_from_tier(tier_clients: Sequence[int], ct: Dict[int, int],
                     tau: int, rng: np.random.Generator) -> List[int]:
    """Participation-balanced pick: lowest ct first (Eq. 4 intent)."""
    if len(tier_clients) <= tau:
        return list(tier_clients)
    noise = rng.permutation(len(tier_clients))
    scored = sorted(zip(tier_clients, noise),
                    key=lambda cn: (ct.get(cn[0], 0), cn[1]))
    return [c for c, _ in scored[:tau]]


def tier_timeouts(tiers: List[List[int]], at: Dict[int, float], beta: float,
                  omega: float) -> List[float]:
    """Eq. 7: D_max^t = min(mean(at over tier) * beta, Omega)."""
    outs = []
    for members in tiers:
        if members:
            mean_at = float(np.mean([at[c] for c in members]))
            outs.append(min(mean_at * beta, omega))
        else:
            outs.append(omega)
    return outs


def gini(counts: Sequence[float]) -> float:
    """Gini coefficient of a participation-count vector (0 = perfectly
    even, -> 1 = one client takes everything).  Zero-count clients must
    be INCLUDED for the number to mean selection fairness."""
    x = np.sort(np.asarray(list(counts), np.float64))
    n = x.size
    total = float(x.sum())
    if n == 0 or total <= 0:
        return 0.0
    cum = np.cumsum(x)
    return float((n + 1 - 2.0 * float((cum / total).sum())) / n)


def participation_fairness(counts: Dict[int, float],
                           population: int = 0) -> Dict[str, float]:
    """Selection-fairness summary over per-client participation counts.

    ``counts`` maps client -> times selected/merged; clients missing
    from it were never picked.  ``population`` (total client count, 0 =
    unknown) pads the vector with the never-selected clients so Gini
    and coverage describe the whole fleet, not just the winners.
    Returns ``gini``, ``coverage`` (fraction selected at least once),
    ``min``/``max``/``mean`` counts over the padded vector.
    """
    vals = [float(v) for v in counts.values()]
    n = max(int(population), len(vals))
    vec = vals + [0.0] * (n - len(vals))
    if not vec:
        return {"gini": 0.0, "coverage": 0.0, "min": 0.0, "max": 0.0,
                "mean": 0.0, "population": 0}
    nonzero = sum(1 for v in vec if v > 0)
    return {"gini": gini(vec), "coverage": nonzero / n,
            "min": float(min(vec)), "max": float(max(vec)),
            "mean": float(np.mean(vec)), "population": n}


def cstt(t: int, v_prev: float, v_now: float, tiers: List[List[int]],
         at: Dict[int, float], ct: Dict[int, int], tau: int, beta: float,
         omega: float, rng: np.random.Generator
         ) -> Tuple[List[Tuple[int, int]], List[float], int]:
    """Alg. 4.  Returns (selected [(client, tier_idx)], D_max per tier,
    new tier pointer t).  Selects tau clients from EVERY tier 1..t."""
    n_tiers = max(len(tiers), 1)
    t = move_tier(min(t, n_tiers), v_now, v_prev, n_tiers)
    selected: List[Tuple[int, int]] = []
    for k in range(t):                      # tiers 1..t (0-indexed k)
        for c in select_from_tier(tiers[k], ct, tau, rng):
            selected.append((c, k))
    d_max = tier_timeouts(tiers, at, beta, omega)
    return selected, d_max, t
