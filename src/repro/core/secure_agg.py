"""Pairwise-mask secure aggregation (Bonawitz et al. style, simplified).

The paper argues synchronous schemes like FedDCT stay compatible with
existing FL privacy protection while asynchronous FL does not (§1, §2).
This module makes that concrete: each pair of surviving clients (i, j)
derives a shared PRG mask m_ij from their pair seed; client i uploads
w_i + sum_{j>i} m_ij - sum_{j<i} m_ji.  Masks cancel exactly in the
weighted sum, so the server learns ONLY the aggregate — and the whole
thing drops into FedDCT's round unchanged, because the survivor set is
fixed when the round's timeout fires (something FedAsync cannot offer:
there is no survivor set, so masks never cancel).

Dropout handling uses the simple "unmask survivors" variant: masks are
generated only over the survivor set announced by the server after the
per-tier timeouts — exactly the set FedDCT's Eq. 5/6 freezes.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _pair_seed(base_seed: int, rnd: int, i: int, j: int) -> int:
    a, b = (i, j) if i < j else (j, i)
    return (base_seed * 1_000_003 + rnd * 8_191 + a * 131_071 + b) % (2 ** 31)


def _mask_like(params, seed: int, scale: float = 1.0):
    """Deterministic PRG mask with the same pytree structure."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(leaves))
    masks = [jax.random.normal(k, l.shape, jnp.float32) * scale
             for k, l in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, masks)


def mask_update(params, client: int, survivors: Sequence[int], rnd: int,
                weight: float, base_seed: int = 0, scale: float = 1.0):
    """Client-side: w_i*s_i + sum of signed pairwise masks.

    Uploads are PRE-weighted (w_i * s_i) so the server's plain sum over
    masked uploads equals sum(s_i * w_i); the server divides by sum(s).
    """
    out = jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32) * weight, params)
    for other in survivors:
        if other == client:
            continue
        m = _mask_like(params, _pair_seed(base_seed, rnd, client, other),
                       scale)
        sign = 1.0 if client < other else -1.0
        out = jax.tree_util.tree_map(lambda a, b: a + sign * b, out, m)
    return out


def secure_aggregate(masked_updates: Sequence, sizes: Sequence[float]):
    """Server-side: plain sum of masked uploads / sum of sizes.

    The server never sees an unmasked individual update.
    """
    total = jax.tree_util.tree_map(lambda *xs: sum(xs), *masked_updates)
    denom = float(np.sum(sizes))
    return jax.tree_util.tree_map(
        lambda t: (t / max(denom, 1e-30)), total)
