"""Tiered client-state residency: hot device rows, cold host rows.

The dense ``ClientStateStore`` is the right shape for thousands of
clients but caps the population at device memory — its ``(N, P)``
buffer must hold every client at once.  ``TieredClientStateStore``
keeps the SAME public API (``gather``/``scatter``/``merge_scatter``/
``flatten``/``unflatten``), so ``engine.train_window`` and the async
runtime are unchanged consumers, but splits residency:

* **hot tier** — a ``(capacity, Pf)`` f32 device buffer (plus the
  ``(capacity, Pi)`` int32 sidecar), holding the rows of active and
  imminent cohorts.  All device programs are the dense store's own
  jitted programs, just addressed by hot SLOT instead of client id, so
  gather/merge/scatter stay one device dispatch each.
* **cold tier** — every other client's row, as pinned host memory
  (``HostColdTier``, sparse: untouched clients cost nothing) or
  spilled to disk in ``checkpoint/ckpt.py`` chunks (``DiskColdTier``).

Both tiers store whatever segment tuple the dense store's row format
defines — ``(f32, int32)`` rows, or ``(int8, f32 scale/zp, int32)``
rows under ``quant_bits=8``, which shrinks the host dict and the disk
chunks ~4x.  Residency moves raw stored segments (bit-exact copies,
never a re-quantization), and all quantize/dequantize math runs the
dense store's standalone shared programs, so quantized tiered
histories stay bit-identical to the quantized DENSE store (while both
differ from f32 by the gated convergence delta).

Residency moves are pure copies of f32/int32 rows (device<->host
round-trips are bit-exact), and every merge runs either the dense
store's fused program or the same folded-merge subgraph compiled
standalone — histories are BIT-IDENTICAL to the dense store on CPU's
sequential row reduction, for any capacity down to 1 (gated in
``tests/test_residency.py`` with randomized op interleavings).

Mechanics:

* promotion (cold -> hot) happens on demand in ``gather``/
  ``merge_scatter``, or ahead of time via ``prefetch`` — the async
  runtime drives it from the ``EventQueue`` lookahead (finish times
  are already in the heap when a window is dispatched, so the NEXT
  window's rows stage host->device while the current cohort trains);
* eviction is LRU over resident clients; ``prefetch(keep=...)`` pins
  the in-flight cohort so staging can never evict what is training;
* demotion is write-behind: only rows dirtied while hot (merged or
  scattered into) are copied back to the cold tier; clean rows are
  dropped for free;
* a cohort wider than the hot tier still works — ``gather`` assembles
  mixed hot/cold row blocks on host, and ``merge_scatter`` (inherited:
  standalone merge program + residency-aware scatter) lands the new
  global row in whichever tier each merged client lives in.  The merge
  program itself never touches the buffers, so its bits cannot depend
  on the residency layout (re-tracing the merge into a buffer-shaped
  jit is NOT bit-stable on XLA CPU — FMA contraction differs per
  compilation unit, the PR 5 kernel-dispatch lesson).

Donation contract (extends the dense store's): the store owns BOTH
tiers.  Callers must not hold references into ``store.buffer``/
``store.int_buffer`` across ``scatter``/``merge_scatter``/``gather``/
``prefetch`` calls — any of them may demote rows and donate the hot
buffers in place — and must not hold references to demoted host rows
either (the cold tier rebinds them on the next write-behind).
``gather``/``gather_one`` return fresh arrays and are always safe.
"""

from __future__ import annotations

import os
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.core.state import ClientStateStore
from repro.obs import telemetry as obs


class HostColdTier:
    """Sparse pinned-host cold tier: client id -> tuple of segment rows.

    The segment layout is whatever ``*templates`` describes — ``(f32
    row, int32 row)`` for the f32 store, ``(int8 row, f32 scale/zp
    row, int32 row)`` for the quantized store, whose cold rows are
    therefore ~4x smaller (dtypes are PRESERVED, never widened).  Rows
    never written read as the template row (the dense store initializes
    every row to the template, so the default is exact), which makes a
    1M-client store cost O(touched clients), not O(N).
    """

    def __init__(self, *templates: np.ndarray):
        # owned copies: device arrays view as read-only, and zero-width
        # np.tile of a read-only row stays read-only
        self._t = tuple(np.array(t) for t in templates)
        self.row_nbytes = int(sum(t.nbytes for t in self._t))
        self._rows: Dict[int, Tuple[np.ndarray, ...]] = {}

    def __len__(self) -> int:
        return len(self._rows)

    @property
    def nbytes(self) -> int:
        """Bytes of materialized cold rows (sparse — untouched clients
        cost nothing)."""
        return len(self._rows) * self.row_nbytes

    def read(self, ids: Sequence[int]):
        """-> tuple of (k, P_seg) row blocks (fresh copies), one per
        segment, template dtypes."""
        idl = [int(c) for c in ids]
        return tuple(
            np.stack([self._rows[c][j] if c in self._rows else t
                      for c in idl])
            for j, t in enumerate(self._t))

    def write(self, ids: Sequence[int], *blocks: np.ndarray) -> None:
        """Write rows for ``ids``.  Broadcast is PER SEGMENT: a 1-D
        block shares one row copy across every id (the scatter-one-
        global-row shape), a 2-D block is per-client — the quantized
        write-around mixes both (per-client int8/meta, one shared
        sidecar row)."""
        blocks = [np.asarray(b, t.dtype) for b, t in zip(blocks, self._t)]
        shared = [b.copy() if b.ndim == 1 else None for b in blocks]
        for k, c in enumerate(ids):
            self._rows[int(c)] = tuple(
                s if s is not None else b[k].copy()
                for s, b in zip(shared, blocks))


class DiskColdTier:
    """Disk-spilled cold tier: rows grouped into fixed-size chunks,
    each persisted as one ``checkpoint/ckpt.py`` npz checkpoint (chunk
    index = step), with a small in-memory LRU of loaded chunks.

    f32/int32 npz round-trips are bit-exact, so spilling through disk
    preserves the tiered store's bit-identity guarantee.
    """

    def __init__(self, ckpt_dir: str, n_rows: int, *templates: np.ndarray,
                 chunk: int = 512, cache_chunks: int = 4):
        if chunk < 1 or cache_chunks < 1:
            raise ValueError("chunk and cache_chunks must be >= 1")
        self.dir = ckpt_dir
        os.makedirs(self.dir, exist_ok=True)
        self.n = int(n_rows)
        self.chunk = int(chunk)
        self.cache_chunks = int(cache_chunks)
        # segment templates, dtypes preserved — quantized stores spill
        # int8 chunks, so their disk footprint shrinks with the rows
        self._t = tuple(np.array(t) for t in templates)
        self.row_nbytes = int(sum(t.nbytes for t in self._t))
        self._cache: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()
        self._dirty: set = set()

    def _rows_in(self, cid: int) -> int:
        return min(self.chunk, self.n - cid * self.chunk)

    @property
    def nbytes(self) -> int:
        """Logical bytes of materialized chunks (on disk or cached)."""
        cids = {int(fn[5:13]) for fn in os.listdir(self.dir)
                if fn.startswith("ckpt_") and fn.endswith(".npz")}
        cids |= set(self._cache)
        return sum(self._rows_in(c) for c in cids) * self.row_nbytes

    def _load(self, cid: int) -> Dict[str, np.ndarray]:
        blk = self._cache.get(cid)
        if blk is not None:
            self._cache.move_to_end(cid)
            return blk
        rows = self._rows_in(cid)
        path = os.path.join(self.dir, f"ckpt_{cid:08d}.npz")
        if os.path.exists(path):
            like = {f"s{j}": np.zeros((rows, t.shape[0]), t.dtype)
                    for j, t in enumerate(self._t)}
            loaded = load_checkpoint(self.dir, cid, like)
            # np.array copies: a loaded device array views as read-only,
            # and chunk blocks must stay writable for row updates
            blk = {f"s{j}": np.array(loaded[f"s{j}"], t.dtype)
                   for j, t in enumerate(self._t)}
        else:
            blk = {f"s{j}": np.tile(t, (rows, 1))
                   for j, t in enumerate(self._t)}
        self._cache[cid] = blk
        while len(self._cache) > self.cache_chunks:
            old_cid, old_blk = self._cache.popitem(last=False)
            if old_cid in self._dirty:
                save_checkpoint(self.dir, old_cid, old_blk)
                self._dirty.discard(old_cid)
        return blk

    def read(self, ids: Sequence[int]):
        outs = [np.empty((len(ids), t.shape[0]), t.dtype)
                for t in self._t]
        for k, c in enumerate(ids):
            c = int(c)
            blk = self._load(c // self.chunk)
            off = c % self.chunk
            for j, o in enumerate(outs):
                o[k] = blk[f"s{j}"][off]
        return tuple(outs)

    def write(self, ids: Sequence[int], *blocks: np.ndarray) -> None:
        # per-segment broadcast, as in HostColdTier.write
        blocks = [np.asarray(b, t.dtype) for b, t in zip(blocks, self._t)]
        for k, c in enumerate(ids):
            c = int(c)
            cid = c // self.chunk
            blk = self._load(cid)
            off = c % self.chunk
            for j, b in enumerate(blocks):
                blk[f"s{j}"][off] = b if b.ndim == 1 else b[k]
            self._dirty.add(cid)

    def flush(self) -> None:
        """Persist every dirty cached chunk (the cache is write-behind
        too; call this before handing the directory to another store)."""
        for cid in sorted(self._dirty):
            save_checkpoint(self.dir, cid, self._cache[cid])
        self._dirty.clear()


class TieredClientStateStore(ClientStateStore):
    """``ClientStateStore`` with hot-device / cold-host row residency.

    ``capacity`` hot rows live on device; the other ``n - capacity``
    rows live in the cold tier (``cold="host"`` pinned memory, or
    ``cold="disk"`` ckpt-chunk spill under ``cold_dir``).  Same public
    API and bit-identical histories as the dense store — see the
    module docstring for the residency mechanics.
    """

    def __init__(self, template_params, n_clients: int, *, capacity: int,
                 cold: str = "host", cold_dir: Optional[str] = None,
                 chunk: int = 512, mesh=None, quant_bits: int = 32,
                 error_feedback: bool = True):
        if mesh is not None and int(getattr(mesh, "size", 1)) > 1:
            raise ValueError(
                "tiered residency manages one device's memory; shard the "
                "dense store over a client mesh instead (mesh= on "
                "ClientStateStore)")
        capacity = int(capacity)
        if capacity < 1:
            raise ValueError(f"hot tier needs >= 1 row, got {capacity}")
        # set before super().__init__ — _buffer_rows() reads it
        self.capacity = min(capacity, int(n_clients))
        super().__init__(template_params, n_clients, mesh=None,
                         quant_bits=quant_bits,
                         error_feedback=error_feedback)
        # cold templates are row 0 of the freshly-initialized hot
        # buffers — guaranteed bit-consistent with every hot row for
        # BOTH row formats (the f32 init tiles the flattened template;
        # the quantized init tiles its quantized image)
        templates = tuple(np.asarray(b[0]) for b in self.bufs)
        if cold == "host":
            self.cold = HostColdTier(*templates)
        elif cold == "disk":
            if not cold_dir:
                raise ValueError("cold='disk' needs cold_dir")
            self.cold = DiskColdTier(cold_dir, self.n, *templates,
                                     chunk=chunk)
        else:
            raise ValueError(f"unknown cold tier {cold!r} "
                             "(expected 'host' or 'disk')")
        self.residency = f"tiered-{cold}"
        # client -> hot slot, insertion order == LRU order (oldest first)
        self._slots: "OrderedDict[int, int]" = OrderedDict()
        self._free: List[int] = list(range(self.capacity))[::-1]
        self._dirty: set = set()
        self.n_promoted = 0
        self.n_demoted = 0

    def _buffer_rows(self) -> int:
        return self.capacity

    def _cold_nbytes(self) -> int:
        return int(self.cold.nbytes)

    # -- residency core -------------------------------------------------
    @property
    def hot_clients(self) -> tuple:
        """Resident client ids, LRU order (oldest first)."""
        return tuple(self._slots)

    def _ensure_hot(self, want: Sequence[int], protect=frozenset(),
                    partial: bool = False,
                    kind: str = "demand") -> List[int]:
        """Make ``want`` (unique client ids) resident in the hot tier.

        Eviction is LRU over residents outside ``protect`` and
        ``want``; dirty victims are written behind to the cold tier
        (one batched device->host read) before their slots are reused,
        and promotions land as one batched host->device write.
        ``partial=True`` (prefetch) stops quietly when every remaining
        slot is pinned instead of raising.  Returns the clients
        actually promoted.

        ``kind`` tags the telemetry counters ("demand" = a gather /
        ensure_window that needed the rows NOW, "prefetch" = lookahead
        staging): the prefetch hit rate is
        ``demand_hit / (demand_hit + demand_promote)`` — the fraction
        of needed rows already resident when asked for.
        """
        want = [int(c) for c in want]
        pinned = {int(c) for c in protect} | set(want)
        staged: List[Tuple[int, int]] = []
        demote_c: List[int] = []
        demote_s: List[int] = []
        n_hit = n_evict_clean = 0
        for c in want:
            if c in self._slots:
                self._slots.move_to_end(c)
                n_hit += 1
                continue
            if self._free:
                slot = self._free.pop()
            else:
                victim = next((v for v in self._slots if v not in pinned),
                              None)
                if victim is None:
                    if partial:
                        break
                    raise RuntimeError(
                        f"hot tier exhausted: capacity {self.capacity} "
                        f"cannot stage {len(set(want))} rows with "
                        f"{len(set(protect))} pinned")
                slot = self._slots.pop(victim)
                if victim in self._dirty:
                    self._dirty.discard(victim)
                    demote_c.append(victim)
                    demote_s.append(slot)
                else:
                    n_evict_clean += 1
            self._slots[c] = slot
            staged.append((c, slot))
        tel = obs.TEL
        # the kind-tagged counter names are f-formatted: build them only
        # while tracing (zero-overhead contract — FED004)
        if tel.enabled and n_hit:
            tel.inc(f"residency.{kind}_hit", n_hit)
        if n_evict_clean:
            tel.inc("residency.evict_clean", n_evict_clean)
        if demote_c:
            # write-behind: read the victims' rows BEFORE the promotion
            # write donates the buffer (np.asarray forces completion)
            with tel.span("residency.write_behind", rows=len(demote_c)):
                blocks = self._fns.read_rows(self.bufs,
                                             self._ids(demote_s))
                self.cold.write(demote_c,
                                *[np.asarray(b) for b in blocks])
            tel.inc("residency.write_behind", len(demote_c))
            self.n_demoted += len(demote_c)
        if staged:
            with tel.span("residency.promote", rows=len(staged),
                          kind=kind):
                cblocks = self.cold.read([c for c, _ in staged])
                self.bufs = self._fns.write_rows(
                    self.bufs, self._ids([s for _, s in staged]),
                    cblocks)
            if tel.enabled:
                tel.inc(f"residency.{kind}_promote", len(staged))
            self.n_promoted += len(staged)
        return [c for c, _ in staged]

    def prefetch(self, client_ids: Sequence[int], keep=()) -> List[int]:
        """EventQueue-driven staging: promote the NEXT window's rows
        while the current cohort trains (the promotion dispatches
        asynchronously; nothing blocks on it).  ``keep`` pins the
        in-flight cohort so staging can never evict what is training.
        Purely a hint — ``gather``/``merge_scatter`` re-stage anything
        missing, so a stale lookahead costs extra swaps, never
        correctness.  Returns the clients actually promoted."""
        uniq = list(dict.fromkeys(int(x) for x in client_ids))
        return self._ensure_hot(uniq[:self.capacity], protect=keep,
                                partial=True, kind="prefetch")

    def ensure_window(self, client_ids: Sequence[int]) -> None:
        """Stage a whole window's rows in one batched promotion (the
        engine calls this before gathering, so the looped per-client
        fallback doesn't promote one row at a time)."""
        uniq = list(dict.fromkeys(int(x) for x in client_ids))
        if len(uniq) <= self.capacity:
            self._ensure_hot(uniq)

    # -- gather / scatter (dense API, residency-aware) ------------------
    def _host_rows(self, idl: List[int]):
        """Assemble (k, P_seg) row blocks for ``idl`` from BOTH tiers
        on host — the cohort-wider-than-capacity gather path.  Device->
        host copies of stored rows are bit-exact (plain int8/f32/int32
        segment moves, never a re-quantization)."""
        uniq = list(dict.fromkeys(idl))
        vals: Dict[int, Tuple[np.ndarray, ...]] = {}
        hot = [c for c in uniq if c in self._slots]
        if hot:
            blocks = self._fns.read_rows(
                self.bufs, self._ids([self._slots[c] for c in hot]))
            blocks = tuple(np.asarray(b) for b in blocks)
            for k, c in enumerate(hot):
                vals[c] = tuple(b[k] for b in blocks)
        missing = [c for c in uniq if c not in self._slots]
        if missing:
            cblocks = self.cold.read(missing)
            for k, c in enumerate(missing):
                vals[c] = tuple(b[k] for b in cblocks)
        return tuple(np.stack([vals[c][j] for c in idl])
                     for j in range(len(self.bufs)))

    def gather(self, ids: Sequence[int]):
        idl = [int(c) for c in ids]
        uniq = list(dict.fromkeys(idl))
        if len(uniq) <= self.capacity:
            self._ensure_hot(uniq)
            slots = self._ids([self._slots[c] for c in idl])
            if self.quant_bits == 8:
                # same read_rows -> from_rows pair as the dense
                # quantized store: ONE dequantize compilation unit
                return self._fns.from_rows(
                    *self._fns.read_rows(self.bufs, slots))
            return self._fns.gather(self.bufs, slots)
        # cohort wider than the hot tier: host-side assembly, no staging
        obs.TEL.inc("residency.oversubscribed_gather", len(uniq))
        with obs.TEL.span("residency.host_gather", rows=len(idl)):
            return self._fns.from_rows(*self._host_rows(idl))

    def gather_one(self, client_id: int):
        c = int(client_id)
        self._ensure_hot([c])
        return self._fns.gather_one(self.bufs, self._slots[c])

    def _scatter_row(self, ids: Sequence[int], frow, irow) -> None:
        """Write one flat global row into every ``ids`` slot, whichever
        tier each row lives in (hot rows in one device program, cold
        rows write-around straight to the cold tier — no promotion).
        Quantized stores quantize per TARGET CLIENT (each has its own
        error-feedback residual) through the same standalone quantize
        program as the dense store, then write the int8/meta blocks
        into hot slots / cold rows — the stored bits cannot depend on
        where the row lives."""
        uniq = list(dict.fromkeys(int(c) for c in ids))
        hot = [c for c in uniq if c in self._slots]
        missing = [c for c in uniq if c not in self._slots]
        if hot:
            slots = self._ids([self._slots[c] for c in hot])
            if self.quant_bits == 8:
                qrows, mrows = self._quantize_for(hot, frow)
                self.bufs = self._fns.write_q(self.bufs, slots, qrows,
                                              mrows, irow)
            else:
                self.bufs = self._fns.scatter(self.bufs, slots, frow,
                                              irow)
            for c in hot:
                self._slots.move_to_end(c)
                self._dirty.add(c)
        if missing:
            obs.TEL.inc("residency.write_around", len(missing))
            if self.quant_bits == 8:
                qrows, mrows = self._quantize_for(missing, frow)
                self.cold.write(missing, np.asarray(qrows),
                                np.asarray(mrows), np.asarray(irow))
            else:
                self.cold.write(missing, np.asarray(frow, np.float32),
                                np.asarray(irow, np.int32))

    def scatter(self, ids: Sequence[int], flat_global):
        frow, irow = self._rows_of(flat_global)
        self._scatter_row(ids, frow, irow)

    def scatter_params(self, ids: Sequence[int], params):
        frow, irow = self._fns.flatten(params)
        self._scatter_row(ids, frow, irow)
        return self._row_value(frow, irow)

    # ``merge_scatter`` is inherited unchanged: the dense store
    # dispatches the standalone merge program (dict-path-identical by
    # construction, independent of buffer height) and lands the new
    # global row through ``scatter_params`` -> ``_scatter_row``, which
    # is residency-aware (hot slots in one device program, cold ids
    # write-around to the cold tier).
