"""Tiered client-state residency: hot device rows, cold host rows.

The dense ``ClientStateStore`` is the right shape for thousands of
clients but caps the population at device memory — its ``(N, P)``
buffer must hold every client at once.  ``TieredClientStateStore``
keeps the SAME public API (``gather``/``scatter``/``merge_scatter``/
``flatten``/``unflatten``), so ``engine.train_window`` and the async
runtime are unchanged consumers, but splits residency:

* **hot tier** — a ``(capacity, Pf)`` f32 device buffer (plus the
  ``(capacity, Pi)`` int32 sidecar), holding the rows of active and
  imminent cohorts.  All device programs are the dense store's own
  jitted programs, just addressed by hot SLOT instead of client id, so
  gather/merge/scatter stay one device dispatch each.
* **cold tier** — every other client's row, as pinned host memory
  (``HostColdTier``, sparse: untouched clients cost nothing) or
  spilled to disk in ``checkpoint/ckpt.py`` chunks (``DiskColdTier``).

Residency moves are pure copies of f32/int32 rows (device<->host
round-trips are bit-exact), and every merge runs either the dense
store's fused program or the same folded-merge subgraph compiled
standalone — histories are BIT-IDENTICAL to the dense store on CPU's
sequential row reduction, for any capacity down to 1 (gated in
``tests/test_residency.py`` with randomized op interleavings).

Mechanics:

* promotion (cold -> hot) happens on demand in ``gather``/
  ``merge_scatter``, or ahead of time via ``prefetch`` — the async
  runtime drives it from the ``EventQueue`` lookahead (finish times
  are already in the heap when a window is dispatched, so the NEXT
  window's rows stage host->device while the current cohort trains);
* eviction is LRU over resident clients; ``prefetch(keep=...)`` pins
  the in-flight cohort so staging can never evict what is training;
* demotion is write-behind: only rows dirtied while hot (merged or
  scattered into) are copied back to the cold tier; clean rows are
  dropped for free;
* a cohort wider than the hot tier still works — ``gather`` assembles
  mixed hot/cold row blocks on host, and ``merge_scatter`` (inherited:
  standalone merge program + residency-aware scatter) lands the new
  global row in whichever tier each merged client lives in.  The merge
  program itself never touches the buffers, so its bits cannot depend
  on the residency layout (re-tracing the merge into a buffer-shaped
  jit is NOT bit-stable on XLA CPU — FMA contraction differs per
  compilation unit, the PR 5 kernel-dispatch lesson).

Donation contract (extends the dense store's): the store owns BOTH
tiers.  Callers must not hold references into ``store.buffer``/
``store.int_buffer`` across ``scatter``/``merge_scatter``/``gather``/
``prefetch`` calls — any of them may demote rows and donate the hot
buffers in place — and must not hold references to demoted host rows
either (the cold tier rebinds them on the next write-behind).
``gather``/``gather_one`` return fresh arrays and are always safe.
"""

from __future__ import annotations

import os
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.core.state import ClientStateStore
from repro.obs import telemetry as obs


class HostColdTier:
    """Sparse pinned-host cold tier: client id -> (f32 row, int32 row).

    Rows never written read as the template row (the dense store
    initializes every row to the template, so the default is exact),
    which makes a 1M-client store cost O(touched clients), not O(N).
    """

    def __init__(self, f_template: np.ndarray, i_template: np.ndarray):
        # owned copies: device arrays view as read-only, and zero-width
        # np.tile of a read-only row stays read-only
        self._f0 = np.array(f_template, np.float32)
        self._i0 = np.array(i_template, np.int32)
        self._rows: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    def __len__(self) -> int:
        return len(self._rows)

    def read(self, ids: Sequence[int]):
        """-> ((k, Pf) f32, (k, Pi) int32) row blocks (fresh copies)."""
        f = np.stack([self._rows[c][0] if c in self._rows else self._f0
                      for c in ids])
        i = np.stack([self._rows[c][1] if c in self._rows else self._i0
                      for c in ids])
        return f, i

    def write(self, ids: Sequence[int], frows: np.ndarray,
              irows: np.ndarray) -> None:
        """Write rows for ``ids``; a 1-D ``frows`` broadcasts one row
        to every id (the scatter-one-global-row shape)."""
        frows = np.asarray(frows, np.float32)
        irows = np.asarray(irows, np.int32)
        if frows.ndim == 1:
            fr, ir = frows.copy(), irows.copy()
            for c in ids:
                self._rows[int(c)] = (fr, ir)
            return
        for k, c in enumerate(ids):
            self._rows[int(c)] = (frows[k].copy(), irows[k].copy())


class DiskColdTier:
    """Disk-spilled cold tier: rows grouped into fixed-size chunks,
    each persisted as one ``checkpoint/ckpt.py`` npz checkpoint (chunk
    index = step), with a small in-memory LRU of loaded chunks.

    f32/int32 npz round-trips are bit-exact, so spilling through disk
    preserves the tiered store's bit-identity guarantee.
    """

    def __init__(self, ckpt_dir: str, n_rows: int, f_template: np.ndarray,
                 i_template: np.ndarray, *, chunk: int = 512,
                 cache_chunks: int = 4):
        if chunk < 1 or cache_chunks < 1:
            raise ValueError("chunk and cache_chunks must be >= 1")
        self.dir = ckpt_dir
        os.makedirs(self.dir, exist_ok=True)
        self.n = int(n_rows)
        self.chunk = int(chunk)
        self.cache_chunks = int(cache_chunks)
        self._f0 = np.array(f_template, np.float32)
        self._i0 = np.array(i_template, np.int32)
        self._cache: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()
        self._dirty: set = set()

    def _rows_in(self, cid: int) -> int:
        return min(self.chunk, self.n - cid * self.chunk)

    def _load(self, cid: int) -> Dict[str, np.ndarray]:
        blk = self._cache.get(cid)
        if blk is not None:
            self._cache.move_to_end(cid)
            return blk
        rows = self._rows_in(cid)
        path = os.path.join(self.dir, f"ckpt_{cid:08d}.npz")
        if os.path.exists(path):
            like = {"f": np.zeros((rows, self._f0.shape[0]), np.float32),
                    "i": np.zeros((rows, self._i0.shape[0]), np.int32)}
            loaded = load_checkpoint(self.dir, cid, like)
            # np.array copies: a loaded device array views as read-only,
            # and chunk blocks must stay writable for row updates
            blk = {"f": np.array(loaded["f"], np.float32),
                   "i": np.array(loaded["i"], np.int32)}
        else:
            blk = {"f": np.tile(self._f0, (rows, 1)),
                   "i": np.tile(self._i0, (rows, 1))}
        self._cache[cid] = blk
        while len(self._cache) > self.cache_chunks:
            old_cid, old_blk = self._cache.popitem(last=False)
            if old_cid in self._dirty:
                save_checkpoint(self.dir, old_cid, old_blk)
                self._dirty.discard(old_cid)
        return blk

    def read(self, ids: Sequence[int]):
        f = np.empty((len(ids), self._f0.shape[0]), np.float32)
        i = np.empty((len(ids), self._i0.shape[0]), np.int32)
        for k, c in enumerate(ids):
            c = int(c)
            blk = self._load(c // self.chunk)
            off = c % self.chunk
            f[k], i[k] = blk["f"][off], blk["i"][off]
        return f, i

    def write(self, ids: Sequence[int], frows: np.ndarray,
              irows: np.ndarray) -> None:
        frows = np.asarray(frows, np.float32)
        irows = np.asarray(irows, np.int32)
        one_row = frows.ndim == 1
        for k, c in enumerate(ids):
            c = int(c)
            cid = c // self.chunk
            blk = self._load(cid)
            off = c % self.chunk
            blk["f"][off] = frows if one_row else frows[k]
            blk["i"][off] = irows if one_row else irows[k]
            self._dirty.add(cid)

    def flush(self) -> None:
        """Persist every dirty cached chunk (the cache is write-behind
        too; call this before handing the directory to another store)."""
        for cid in sorted(self._dirty):
            save_checkpoint(self.dir, cid, self._cache[cid])
        self._dirty.clear()


class TieredClientStateStore(ClientStateStore):
    """``ClientStateStore`` with hot-device / cold-host row residency.

    ``capacity`` hot rows live on device; the other ``n - capacity``
    rows live in the cold tier (``cold="host"`` pinned memory, or
    ``cold="disk"`` ckpt-chunk spill under ``cold_dir``).  Same public
    API and bit-identical histories as the dense store — see the
    module docstring for the residency mechanics.
    """

    def __init__(self, template_params, n_clients: int, *, capacity: int,
                 cold: str = "host", cold_dir: Optional[str] = None,
                 chunk: int = 512, mesh=None):
        if mesh is not None and int(getattr(mesh, "size", 1)) > 1:
            raise ValueError(
                "tiered residency manages one device's memory; shard the "
                "dense store over a client mesh instead (mesh= on "
                "ClientStateStore)")
        capacity = int(capacity)
        if capacity < 1:
            raise ValueError(f"hot tier needs >= 1 row, got {capacity}")
        # set before super().__init__ — _buffer_rows() reads it
        self.capacity = min(capacity, int(n_clients))
        super().__init__(template_params, n_clients, mesh=None)
        frow, irow = self._fns.flatten(template_params)
        f0, i0 = np.asarray(frow, np.float32), np.asarray(irow, np.int32)
        if cold == "host":
            self.cold = HostColdTier(f0, i0)
        elif cold == "disk":
            if not cold_dir:
                raise ValueError("cold='disk' needs cold_dir")
            self.cold = DiskColdTier(cold_dir, self.n, f0, i0, chunk=chunk)
        else:
            raise ValueError(f"unknown cold tier {cold!r} "
                             "(expected 'host' or 'disk')")
        self.residency = f"tiered-{cold}"
        # client -> hot slot, insertion order == LRU order (oldest first)
        self._slots: "OrderedDict[int, int]" = OrderedDict()
        self._free: List[int] = list(range(self.capacity))[::-1]
        self._dirty: set = set()
        self.n_promoted = 0
        self.n_demoted = 0

    def _buffer_rows(self) -> int:
        return self.capacity

    # -- residency core -------------------------------------------------
    @property
    def hot_clients(self) -> tuple:
        """Resident client ids, LRU order (oldest first)."""
        return tuple(self._slots)

    def _ensure_hot(self, want: Sequence[int], protect=frozenset(),
                    partial: bool = False,
                    kind: str = "demand") -> List[int]:
        """Make ``want`` (unique client ids) resident in the hot tier.

        Eviction is LRU over residents outside ``protect`` and
        ``want``; dirty victims are written behind to the cold tier
        (one batched device->host read) before their slots are reused,
        and promotions land as one batched host->device write.
        ``partial=True`` (prefetch) stops quietly when every remaining
        slot is pinned instead of raising.  Returns the clients
        actually promoted.

        ``kind`` tags the telemetry counters ("demand" = a gather /
        ensure_window that needed the rows NOW, "prefetch" = lookahead
        staging): the prefetch hit rate is
        ``demand_hit / (demand_hit + demand_promote)`` — the fraction
        of needed rows already resident when asked for.
        """
        want = [int(c) for c in want]
        pinned = {int(c) for c in protect} | set(want)
        staged: List[Tuple[int, int]] = []
        demote_c: List[int] = []
        demote_s: List[int] = []
        n_hit = n_evict_clean = 0
        for c in want:
            if c in self._slots:
                self._slots.move_to_end(c)
                n_hit += 1
                continue
            if self._free:
                slot = self._free.pop()
            else:
                victim = next((v for v in self._slots if v not in pinned),
                              None)
                if victim is None:
                    if partial:
                        break
                    raise RuntimeError(
                        f"hot tier exhausted: capacity {self.capacity} "
                        f"cannot stage {len(set(want))} rows with "
                        f"{len(set(protect))} pinned")
                slot = self._slots.pop(victim)
                if victim in self._dirty:
                    self._dirty.discard(victim)
                    demote_c.append(victim)
                    demote_s.append(slot)
                else:
                    n_evict_clean += 1
            self._slots[c] = slot
            staged.append((c, slot))
        tel = obs.TEL
        if n_hit:
            tel.inc(f"residency.{kind}_hit", n_hit)
        if n_evict_clean:
            tel.inc("residency.evict_clean", n_evict_clean)
        if demote_c:
            # write-behind: read the victims' rows BEFORE the promotion
            # write donates the buffer (np.asarray forces completion)
            with tel.span("residency.write_behind", rows=len(demote_c)):
                frows, irows = self._fns.read_rows(self.buf, self.ibuf,
                                                   self._ids(demote_s))
                self.cold.write(demote_c, np.asarray(frows),
                                np.asarray(irows))
            tel.inc("residency.write_behind", len(demote_c))
            self.n_demoted += len(demote_c)
        if staged:
            with tel.span("residency.promote", rows=len(staged),
                          kind=kind):
                cf, ci = self.cold.read([c for c, _ in staged])
                self.buf, self.ibuf = self._fns.write_rows(
                    self.buf, self.ibuf,
                    self._ids([s for _, s in staged]), cf, ci)
            tel.inc(f"residency.{kind}_promote", len(staged))
            self.n_promoted += len(staged)
        return [c for c, _ in staged]

    def prefetch(self, client_ids: Sequence[int], keep=()) -> List[int]:
        """EventQueue-driven staging: promote the NEXT window's rows
        while the current cohort trains (the promotion dispatches
        asynchronously; nothing blocks on it).  ``keep`` pins the
        in-flight cohort so staging can never evict what is training.
        Purely a hint — ``gather``/``merge_scatter`` re-stage anything
        missing, so a stale lookahead costs extra swaps, never
        correctness.  Returns the clients actually promoted."""
        uniq = list(dict.fromkeys(int(x) for x in client_ids))
        return self._ensure_hot(uniq[:self.capacity], protect=keep,
                                partial=True, kind="prefetch")

    def ensure_window(self, client_ids: Sequence[int]) -> None:
        """Stage a whole window's rows in one batched promotion (the
        engine calls this before gathering, so the looped per-client
        fallback doesn't promote one row at a time)."""
        uniq = list(dict.fromkeys(int(x) for x in client_ids))
        if len(uniq) <= self.capacity:
            self._ensure_hot(uniq)

    # -- gather / scatter (dense API, residency-aware) ------------------
    def _host_rows(self, idl: List[int]):
        """Assemble (k, Pf)/(k, Pi) row blocks for ``idl`` from BOTH
        tiers on host — the cohort-wider-than-capacity gather path.
        Device->host copies of f32/int32 rows are bit-exact."""
        uniq = list(dict.fromkeys(idl))
        vals: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        hot = [c for c in uniq if c in self._slots]
        if hot:
            frows, irows = self._fns.read_rows(
                self.buf, self.ibuf,
                self._ids([self._slots[c] for c in hot]))
            frows, irows = np.asarray(frows), np.asarray(irows)
            for k, c in enumerate(hot):
                vals[c] = (frows[k], irows[k])
        missing = [c for c in uniq if c not in self._slots]
        if missing:
            cf, ci = self.cold.read(missing)
            for k, c in enumerate(missing):
                vals[c] = (cf[k], ci[k])
        f = np.stack([vals[c][0] for c in idl])
        i = np.stack([vals[c][1] for c in idl])
        return f, i

    def gather(self, ids: Sequence[int]):
        idl = [int(c) for c in ids]
        uniq = list(dict.fromkeys(idl))
        if len(uniq) <= self.capacity:
            self._ensure_hot(uniq)
            slots = [self._slots[c] for c in idl]
            return self._fns.gather(self.buf, self.ibuf, self._ids(slots))
        # cohort wider than the hot tier: host-side assembly, no staging
        obs.TEL.inc("residency.oversubscribed_gather", len(uniq))
        with obs.TEL.span("residency.host_gather", rows=len(idl)):
            f, i = self._host_rows(idl)
            return self._fns.from_rows(f, i)

    def gather_one(self, client_id: int):
        c = int(client_id)
        self._ensure_hot([c])
        return self._fns.gather_one(self.buf, self.ibuf, self._slots[c])

    def _scatter_row(self, ids: Sequence[int], frow, irow) -> None:
        """Write one flat global row into every ``ids`` slot, whichever
        tier each row lives in (hot rows in one device program, cold
        rows write-around straight to the cold tier — no promotion)."""
        uniq = list(dict.fromkeys(int(c) for c in ids))
        hot = [c for c in uniq if c in self._slots]
        if hot:
            self.buf, self.ibuf = self._fns.scatter(
                self.buf, self.ibuf,
                self._ids([self._slots[c] for c in hot]), frow, irow)
            for c in hot:
                self._slots.move_to_end(c)
                self._dirty.add(c)
        missing = [c for c in uniq if c not in self._slots]
        if missing:
            obs.TEL.inc("residency.write_around", len(missing))
            self.cold.write(missing, np.asarray(frow, np.float32),
                            np.asarray(irow, np.int32))

    def scatter(self, ids: Sequence[int], flat_global):
        frow, irow = self._rows_of(flat_global)
        self._scatter_row(ids, frow, irow)

    def scatter_params(self, ids: Sequence[int], params):
        frow, irow = self._fns.flatten(params)
        self._scatter_row(ids, frow, irow)
        return self._row_value(frow, irow)

    # ``merge_scatter`` is inherited unchanged: the dense store
    # dispatches the standalone merge program (dict-path-identical by
    # construction, independent of buffer height) and lands the new
    # global row through ``scatter_params`` -> ``_scatter_row``, which
    # is residency-aware (hot slots in one device program, cold ids
    # write-around to the cold tier).
