"""Batched multi-client execution engine — the server's round hot path.

The seed implementation trained selected clients one at a time in a
Python ``for`` loop and aggregated a Python list of per-client pytrees.
That caps real wall-clock throughput at ``C * T`` eager dispatches per
round, so the paper's simulated-time gains never became real-time
gains.  ``BatchedClientEngine`` replaces that:

* local training for the whole cohort runs as ONE jitted program
  (``trainer.local_train_batch``: vmap over clients of a lax.scan over
  local steps) producing a stacked update pytree with a leading client
  axis — no per-client host round-trips;
* aggregation reduces the stacked pytree on device
  (``weighted_average_stacked``), optionally through the pytree-native
  Pallas fedagg path (single flattened (N, P) kernel pass with fused
  weight normalization + straggler masking).

Trainers that cannot batch (no ``local_train_batch``, or a custom pjit
step) transparently fall back to the looped path with identical
semantics, so schedulers are written against the engine only.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (aggregate_or_keep,
                                    staleness_merge_coefficients,
                                    staleness_weighted_merge,
                                    weighted_average_stacked)
from repro.obs import flstats
from repro.obs import telemetry as obs


class BatchedClientEngine:
    """Executes a cohort of clients and aggregates them without leaving
    device.  One instance per run (it owns no model state)."""

    def __init__(self, trainer, *, use_kernel_agg: bool = False,
                 interpret: Optional[bool] = None,
                 force_looped: bool = False, pad_cohorts: bool = True):
        self.trainer = trainer
        self.use_kernel_agg = use_kernel_agg
        self.interpret = interpret
        self.force_looped = force_looped
        # pad cohort size up to a power of two so jit retraces O(log C)
        # distinct shapes instead of one per cohort size; pad rows are
        # duplicates of the last client and are sliced off again.
        self.pad_cohorts = pad_cohorts
        self._can_batch = (not force_looped
                           and hasattr(trainer, "local_train_batch"))
        self._can_cohort = (not force_looped
                            and hasattr(trainer, "local_train_cohort"))

    # -- local training -------------------------------------------------
    def _pad_target(self, n: int) -> int:
        """Padded cohort size for ``n`` clients (subclass hook: the
        sharded engine also rounds up to a mesh multiple)."""
        return 1 << (n - 1).bit_length()

    def _pad_pow2(self, *lists):
        """Pad parallel per-client lists up to ``_pad_target`` by
        repeating their last element (see ``pad_cohorts``)."""
        if not self.pad_cohorts:
            return lists
        n = len(lists[0])
        target = self._pad_target(n)
        return tuple(l + [l[-1]] * (target - n) for l in lists)

    def _local_train_batch(self, params, ids, rnd_seed):
        """Trainer dispatch hook (the sharded engine injects its
        ``wrap`` here)."""
        return self.trainer.local_train_batch(params, ids, rnd_seed)

    def _local_train_cohort(self, stacked_starts, ids, seeds):
        return self.trainer.local_train_cohort(stacked_starts, ids, seeds)

    def train_clients(self, params, client_ids: Sequence[int],
                      rnd_seed: int):
        """-> (stacked update pytree with leading axis len(client_ids),
        sizes (len(client_ids),) f32).  Empty cohort -> (None, empty)."""
        ids = [int(c) for c in client_ids]
        if not ids:
            return None, np.zeros((0,), np.float32)
        if self._can_batch:
            n = len(ids)
            (run_ids,) = self._pad_pow2(ids)
            try:
                stacked, sizes = self._local_train_batch(
                    params, run_ids, rnd_seed)
                if len(run_ids) != n:
                    stacked = jax.tree_util.tree_map(
                        lambda l: l[:n], stacked)
                    sizes = sizes[:n]
                return stacked, sizes
            except NotImplementedError:
                self._can_batch = False
        outs = [self.trainer.local_train(params, c, rnd_seed=rnd_seed)
                for c in ids]
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[p for p, _ in outs])
        sizes = np.asarray([s for _, s in outs], np.float32)
        return stacked, sizes

    def train_cohort(self, start_params: Sequence, client_ids: Sequence[int],
                     rnd_seeds: Sequence[int]):
        """Async-window cohort: client i trains from its OWN snapshot
        ``start_params[i]`` with its own data-stream seed.

        -> (stacked update pytree with leading axis len(client_ids),
        sizes (len(client_ids),) f32).  Empty cohort -> (None, empty).
        Falls back to looping ``local_train`` per client when the
        trainer lacks ``local_train_cohort``.
        """
        ids = [int(c) for c in client_ids]
        seeds = [int(s) for s in rnd_seeds]
        starts = list(start_params)
        if not ids:
            return None, np.zeros((0,), np.float32)
        if self._can_cohort:
            n = len(ids)
            run_ids, run_seeds, run_starts = self._pad_pow2(ids, seeds,
                                                            starts)
            stacked_starts = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *run_starts)
            try:
                stacked, sizes = self._local_train_cohort(
                    stacked_starts, run_ids, run_seeds)
                if len(run_ids) != n:
                    stacked = jax.tree_util.tree_map(
                        lambda l: l[:n], stacked)
                    sizes = sizes[:n]
                return stacked, sizes
            except NotImplementedError:
                self._can_cohort = False
        outs = [self.trainer.local_train(p0, c, rnd_seed=s)
                for p0, c, s in zip(starts, ids, seeds)]
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[p for p, _ in outs])
        sizes = np.asarray([s for _, s in outs], np.float32)
        return stacked, sizes

    # -- aggregation ----------------------------------------------------
    def aggregate(self, stacked, weights):
        """Weighted average of the stacked cohort; zero-weight rows are
        masked stragglers and contribute nothing."""
        return weighted_average_stacked(
            stacked, weights, use_kernel=self.use_kernel_agg,
            interpret=self.interpret)

    def merge_staleness(self, params, stacked, alphas):
        """Fused staleness-weighted window merge (async runtime): the
        batched equivalent of folding ``staleness_merge`` over the
        stacked rows, one device reduction."""
        return staleness_weighted_merge(
            params, stacked, alphas, use_kernel=self.use_kernel_agg,
            interpret=self.interpret)

    def aggregate_or_keep(self, params, stacked, weights):
        """``aggregate`` with the all-masked guard on device: a
        ``lax.cond`` keeps ``params`` when every effective weight is
        zero, so the round never syncs a weight sum to the host."""
        return aggregate_or_keep(params, stacked, weights,
                                 use_kernel=self.use_kernel_agg,
                                 interpret=self.interpret)

    # -- fused round ----------------------------------------------------
    def train_round(self, params, client_ids: Sequence[int], rnd_seed: int,
                    weights: Optional[Sequence[float]] = None):
        """Train the cohort and aggregate the survivors.

        ``weights`` defaults to per-client sample counts; pass an
        explicit vector (zeros for masked clients) to drop updates
        without re-packing.  An empty cohort (all-straggler round)
        returns ``params`` unchanged — the FedDCT Alg. 2 convention —
        decided host-side BEFORE training; the all-masked (every
        survivor zero-weighted) guard lives on device.
        """
        tel = obs.TEL
        with tel.span("round.train", cohort=len(client_ids)):
            stacked, sizes = self.train_clients(params, client_ids,
                                                rnd_seed)
        if stacked is None:
            return params
        w = sizes if weights is None else np.asarray(  # fedlint: disable=FED002 -- weights is a host Sequence[float] from the caller, packing not a device readback
            weights, np.float32)
        with tel.span("round.aggregate", cohort=len(client_ids)):
            return self.aggregate_or_keep(params, stacked, w)

    # -- fused store-backed async window --------------------------------
    def train_window(self, store, params, client_ids: Sequence[int],
                     rnd_seeds: Sequence[int], alphas: Sequence[float]):
        """One drained async window against a ``ClientStateStore``:
        gather cohort snapshots -> cohort train -> folded staleness
        merge (zero-coefficient straggler/pad masking) -> scatter the
        new global row back into the merged clients' slots.

        The snapshot gather, the merge, the new-global flatten and the
        scatter each run as one device program per padded cohort-size
        bucket (the merge+scatter program donates the store buffers);
        padded rows ride through the merge with coefficient 0 instead
        of being sliced off, so there is no post-hoc host repack.  The
        merge dispatches the folded Pallas fedagg kernel when the
        engine was built with ``use_kernel_agg`` (interpret-mode on
        CPU, compiled on TPU) — the same program the dict path runs.
        Returns ``(new_params, new_global_flat)``.

        Row format is the STORE's concern: under ``quant_bits=8`` the
        gather dequantizes int8 rows into the cohort's f32 start
        params and the scatter re-quantizes the merged row per client
        (error-feedback residual folded in), so this window step is
        the per-window quantize -> store -> dequantize cycle without a
        single engine-side branch.
        """
        ids = [int(c) for c in client_ids]
        seeds = [int(s) for s in rnd_seeds]
        n = len(ids)
        if n == 0:
            return params, store.flatten(params)
        tel = obs.TEL
        coef = staleness_merge_coefficients(alphas)
        merge_kw = dict(use_kernel=self.use_kernel_agg,
                        interpret=self.interpret)
        # residency hook (duck-typed; dense stores don't have it): a
        # tiered store stages the whole window's rows in one batched
        # host->device promotion, so the looped fallback doesn't
        # promote one row per gather_one.
        stage = getattr(store, "ensure_window", None)
        if stage is not None:
            with tel.span("window.stage", cohort=n):
                stage(ids)
        if self._can_cohort:
            run_ids, run_seeds = self._pad_pow2(ids, seeds)
            with tel.span("window.gather", rows=len(run_ids)):
                starts = store.gather(run_ids)
            try:
                with tel.span("window.train", cohort=n,
                              padded=len(run_ids)):
                    stacked, _ = self._local_train_cohort(starts, run_ids,
                                                          run_seeds)
                flstats.record_update_norm(stacked, n)
                pad = np.zeros(len(run_ids) - n, np.float32)
                with tel.span("window.merge_scatter", rows=len(run_ids)):
                    return store.merge_scatter(
                        run_ids, stacked, np.concatenate([coef, pad]),
                        params, **merge_kw)
            except NotImplementedError:
                self._can_cohort = False
        # looped fallback (trainers without local_train_cohort): rows
        # still merge + scatter through the store's fused program.
        with tel.span("window.train", cohort=n, looped=True):
            outs = [self.trainer.local_train(store.gather_one(c), c,
                                             rnd_seed=s)
                    for c, s in zip(ids, seeds)]
        run_ids, trees = self._pad_pow2(ids, [p for p, _ in outs])
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *trees)
        pad = np.zeros(len(run_ids) - n, np.float32)
        with tel.span("window.merge_scatter", rows=len(run_ids)):
            return store.merge_scatter(run_ids, stacked,
                                       np.concatenate([coef, pad]), params,
                                       **merge_kw)


def make_engine(trainer, *, use_kernel_agg: bool = False,
                engine: str = "batched",
                interpret: Optional[bool] = None,
                mesh=None) -> BatchedClientEngine:
    """``engine``: "batched" (default) or "looped" (reference path for
    equivalence tests and A/B benchmarks).

    ``mesh``: a 1-D client mesh (``repro.distributed.make_client_mesh``)
    to shard cohorts across devices.  ``None`` or a single-device mesh
    selects the plain single-device engine — with one device the
    distributed path IS today's engine, so histories stay bit-identical
    by construction; a multi-device mesh returns the shard_map-backed
    ``ShardedClientEngine``.
    """
    if engine not in ("batched", "looped"):
        raise ValueError(f"unknown engine {engine!r}")
    if mesh is not None and int(mesh.size) > 1:
        if engine == "looped":
            raise ValueError("the looped reference engine cannot shard; "
                             "use engine='batched' with a client mesh")
        from repro.distributed.engine import ShardedClientEngine
        return ShardedClientEngine(trainer, mesh,
                                   use_kernel_agg=use_kernel_agg,
                                   interpret=interpret)
    return BatchedClientEngine(trainer, use_kernel_agg=use_kernel_agg,
                               interpret=interpret,
                               force_looped=(engine == "looped"))
