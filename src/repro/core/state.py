"""Device-resident flat client-state store — where client models LIVE.

The async runtime (PR 2) kept per-client model snapshots as a Python
``Dict[int, pytree]``: N full scattered copies of the model, re-stacked
leaf by leaf (``tree_map(jnp.stack)``) on every drained window before
the cohort could train.  The flatten-once ``(N, P)`` representation the
Pallas fedagg kernel already uses for aggregation is the natural home
for that state instead: ``ClientStateStore`` holds every client's
snapshot as one row of a single device-resident ``(N, P)`` f32 buffer
— plus, for models that carry non-float state (step counters, masks),
a sidecar ``(N, Pi)`` int32 segment — with the unflatten spec (per-leaf
segment/offset/shape/dtype views) cached once at construction.

* ``gather(ids)`` returns the stacked start-params pytree for a cohort
  (one device program: row gather + per-leaf slice/reshape/cast) — no
  per-leaf host stacking, no dict lookups.
* ``scatter(ids, flat_global)`` writes one global row into the merged
  clients' slots via ``buf.at[ids].set(...)`` under a jit that DONATES
  the buffers (donation is applied on accelerator backends; XLA CPU
  does not implement donation, so it is skipped there to avoid
  warnings), so the store updates in place instead of copying N*P
  floats per window.
* ``merge_scatter(ids, stacked_updates, coef, global_flat)`` is the
  fused tail of the async round step: staleness merge (global model as
  the implicit row 0, zero-coefficient rows masked to exact no-ops —
  the straggler-mask convention, which also makes padded rows free) +
  flatten of the new global row + scatter, ONE jitted buffer-donating
  program per padded cohort-size bucket.  ``use_kernel=True``
  dispatches the merge through the folded Pallas fedagg kernel
  (``fedagg_fold_pytree`` — interpret-mode on CPU, compiled on TPU),
  the SAME program the dict-of-pytrees reference's
  ``staleness_weighted_merge(use_kernel=True)`` runs, so kernel-path
  histories stay bit-identical between the two snapshot paths.

Donation contract: the store owns its buffers.  Callers must NOT hold
references into ``store.buffer``/``store.int_buffer`` across
``scatter``/``merge_scatter`` calls — on donating backends the old
buffer is invalidated in place.  ``gather``/``gather_one`` return
fresh arrays and are always safe.

Sharding: pass a 1-D client mesh to shard the row axis across devices
(rows padded to a mesh multiple via ``ClientShardingPlan`` — the extra
rows are never addressed).  Gather/merge/scatter then run as GSPMD
programs over the row-sharded buffers, composing with the sharded
engine's cohort padding.

Dtype note (segment layout): f32/bf16/f16 leaves live in the f32 row
segment (every bf16/f16 value is exactly representable in f32 — exact
round-trip).  bool and integer leaves of <= 32 bits live in the int32
sidecar segment: bool/int8/int16/int32/uint8/uint16 values embed
exactly in int32 (plain ``astype`` both ways); uint32 round-trips via
``lax.bitcast_convert_type`` (bit pattern preserved).  Leaves the
store cannot carry exactly — 64-bit ints, f64, complex — are rejected
at construction with ``TypeError``.
"""

from __future__ import annotations

import functools
from types import SimpleNamespace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import _merge_folded_jnp
from repro.kernels.ops import fedagg_fold_pytree, on_cpu, tree_spec
from repro.obs import telemetry as obs

_FLOAT_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def _leaf_kind(dtype) -> str:
    """Segment + conversion rule of one leaf dtype: "f" (f32 segment),
    "i" (int32 sidecar, value-exact astype), "u32" (int32 sidecar,
    bitcast).  Raises TypeError for dtypes with no exact carrier."""
    d = jnp.dtype(dtype)
    if d in [jnp.dtype(x) for x in _FLOAT_DTYPES]:
        return "f"
    if d == jnp.dtype(jnp.uint32):
        return "u32"
    if (np.issubdtype(d, np.integer) or d == np.dtype(bool)) \
            and d.itemsize <= 4:
        return "i"
    raise TypeError(
        f"ClientStateStore rows are f32 + int32 segments: leaf dtype "
        f"{dtype} does not round-trip exactly (float leaves up to f32 "
        "and bool/int leaves up to 32 bits only)")


def _segment_entries(spec):
    """tree_spec entries -> per-leaf (kind, segment offset, size, shape,
    dtype) with float and sidecar offsets accumulated independently.
    Returns (entries, float width Pf, sidecar width Pi)."""
    entries, f_off, i_off = [], 0, 0
    for _, size, shape, dtype in spec:
        kind = _leaf_kind(dtype)
        if kind == "f":
            entries.append((kind, f_off, size, shape, dtype))
            f_off += size
        else:
            entries.append((kind, i_off, size, shape, dtype))
            i_off += size
    return tuple(entries), f_off, i_off


def _to_rows(tree, entries):
    """Model pytree -> ((Pf,) f32 row, (Pi,) int32 row); either row may
    be zero-width."""
    leaves = jax.tree_util.tree_leaves(tree)
    f_parts, i_parts = [], []
    for l, (kind, _, _, _, _) in zip(leaves, entries):
        x = jnp.asarray(l)
        if kind == "f":
            f_parts.append(x.reshape(-1).astype(jnp.float32))
        elif kind == "i":
            i_parts.append(x.reshape(-1).astype(jnp.int32))
        else:
            i_parts.append(
                jax.lax.bitcast_convert_type(x, jnp.int32).reshape(-1))
    frow = (jnp.concatenate(f_parts) if f_parts
            else jnp.zeros((0,), jnp.float32))
    irow = (jnp.concatenate(i_parts) if i_parts
            else jnp.zeros((0,), jnp.int32))
    return frow, irow


def _leaf_from(seg, off, size, lead, kind, shape, dtype):
    x = seg[..., off:off + size].reshape(lead + shape)
    if kind == "u32":
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    return x.astype(dtype)


def _from_rows(frow, irow, treedef, entries):
    """((Pf,), (Pi,)) rows -> model pytree (exact per-leaf dtypes)."""
    outs = [_leaf_from(frow if kind == "f" else irow, off, size, (),
                       kind, shape, dtype)
            for kind, off, size, shape, dtype in entries]
    return jax.tree_util.tree_unflatten(treedef, outs)


def _from_stacked_rows(frows, irows, treedef, entries):
    """((K, Pf), (K, Pi)) row blocks -> stacked pytree, leaves (K, ...)."""
    k = frows.shape[0]
    outs = [_leaf_from(frows if kind == "f" else irows, off, size, (k,),
                       kind, shape, dtype)
            for kind, off, size, shape, dtype in entries]
    return jax.tree_util.tree_unflatten(treedef, outs)


@functools.lru_cache(maxsize=None)
def _programs(treedef, entries, donate: bool):
    """Jitted store programs, cached per (tree structure, segment
    layout, donation mode) so every store over the same model family
    shares compiled code — a fresh store per run costs zero recompiles."""

    def flatten_impl(tree):
        return _to_rows(tree, entries)

    def unflatten_impl(frow, irow):
        return _from_rows(frow, irow, treedef, entries)

    def gather_impl(fbuf, ibuf, ids):
        return _from_stacked_rows(fbuf[ids], ibuf[ids], treedef, entries)

    def gather_one_impl(fbuf, ibuf, i):
        return _from_rows(fbuf[i], ibuf[i], treedef, entries)

    def from_rows_impl(frows, irows):
        # stacked pytree straight from materialized row blocks — the
        # tiered store's mixed hot/cold gather (rows assembled on host)
        return _from_stacked_rows(frows, irows, treedef, entries)

    def read_rows_impl(fbuf, ibuf, ids):
        # raw row blocks (write-behind demotion reads these before the
        # slots are reused); never donated — it only reads
        return fbuf[ids], ibuf[ids]

    def write_rows_impl(fbuf, ibuf, ids, frows, irows):
        # per-row block write (host->device promotion)
        return fbuf.at[ids].set(frows), ibuf.at[ids].set(irows)

    def scatter_impl(fbuf, ibuf, ids, frow, irow):
        return fbuf.at[ids].set(frow), ibuf.at[ids].set(irow)

    def scatter_params_impl(fbuf, ibuf, ids, params):
        frow, irow = flatten_impl(params)
        return (fbuf.at[ids].set(frow), ibuf.at[ids].set(irow),
                frow, irow)

    def init_impl(params, rows):
        frow, irow = flatten_impl(params)
        return (jnp.tile(frow[None], (rows, 1)),
                jnp.tile(irow[None], (rows, 1)))

    dk = dict(donate_argnums=(0, 1)) if donate else {}
    return SimpleNamespace(
        flatten=jax.jit(flatten_impl),
        unflatten=jax.jit(unflatten_impl),
        gather=jax.jit(gather_impl),
        gather_one=jax.jit(gather_one_impl),
        from_rows=jax.jit(from_rows_impl),
        read_rows=jax.jit(read_rows_impl),
        write_rows=jax.jit(write_rows_impl, **dk),
        scatter=jax.jit(scatter_impl, **dk),
        scatter_params=jax.jit(scatter_params_impl, **dk),
        init=jax.jit(init_impl, static_argnums=(1,)),
    )


class ClientStateStore:
    """All N client model snapshots as one device-resident (N, Pf) f32
    buffer plus an optional (N, Pi) int32 sidecar for non-float leaves.
    One instance per run; it owns the buffers (see the donation
    contract in the module docstring)."""

    def __init__(self, template_params, n_clients: int, *, mesh=None):
        if n_clients < 1:
            raise ValueError(f"need at least one client, got {n_clients}")
        treedef, spec, _ = tree_spec(template_params)
        self.treedef, self.spec = treedef, spec
        self.entries, self.p, self.pi = _segment_entries(spec)
        self.n = int(n_clients)
        self.mesh = mesh if (mesh is not None and int(mesh.size) > 1) \
            else None
        self.rows = self._buffer_rows()
        # dense: every client's authoritative row lives on device.  The
        # tiered subclass overrides this tag ("tiered-host"/"tiered-disk").
        self.residency = "dense"
        # XLA CPU does not implement buffer donation — donating there
        # only emits warnings.  Donate on real accelerator backends.
        self._donate = jax.default_backend() != "cpu"
        obs.TEL.inc("store.donation_active" if self._donate
                    else "store.donation_skipped")
        self._fns = _programs(treedef, self.entries, self._donate)
        fbuf, ibuf = self._fns.init(template_params, self.rows)
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            rows_sharded = NamedSharding(self.mesh,
                                         P(self.mesh.axis_names[0]))
            fbuf = jax.device_put(fbuf, rows_sharded)
            ibuf = jax.device_put(ibuf, rows_sharded)
        self.buf, self.ibuf = fbuf, ibuf

    def _buffer_rows(self) -> int:
        """Height of the device-resident buffer (subclass hook: the
        tiered store allocates only its hot capacity)."""
        if self.mesh is not None:
            from repro.distributed.plan import ClientShardingPlan
            return ClientShardingPlan.for_cohort(self.n, self.mesh).padded_n
        return self.n

    @staticmethod
    def _ids(ids) -> jnp.ndarray:
        return jnp.asarray(np.asarray(ids, np.int32))

    def _rows_of(self, flat):
        """Public row value -> (frow, irow) pair.  Stores WITH a
        sidecar exchange ``(frow, irow)`` tuples; all-float stores keep
        the PR 4 plain-(P,) row convention."""
        if self.pi:
            frow, irow = flat
            return frow, irow
        return flat, jnp.zeros((0,), jnp.int32)

    def _row_value(self, frow, irow):
        return (frow, irow) if self.pi else frow

    # -- flat <-> pytree views ------------------------------------------
    @property
    def buffer(self):
        """The (rows, Pf) f32 buffer.  Read-only by convention — do not
        hold a reference across scatter/merge_scatter (donation)."""
        return self.buf

    @property
    def int_buffer(self):
        """The (rows, Pi) int32 sidecar (zero-width when the template
        has float leaves only).  Same donation contract as ``buffer``."""
        return self.ibuf

    def flatten(self, params):
        """Model pytree -> flat row (one jitted concat): a (Pf,) f32
        array, or a ``(f32 row, int32 row)`` pair when the template has
        non-float leaves."""
        frow, irow = self._fns.flatten(params)
        return self._row_value(frow, irow)

    def unflatten(self, flat):
        """Flat row (``flatten``'s convention) -> model pytree with
        per-leaf shapes/dtypes."""
        frow, irow = self._rows_of(flat)
        return self._fns.unflatten(frow, irow)

    # -- gather / scatter -----------------------------------------------
    def gather(self, ids: Sequence[int]):
        """-> stacked start-params pytree, leaves (len(ids), ...).

        One device program per ids-length bucket (callers pad cohorts
        — the engine's pow2/mesh convention — to bound retraces).
        Duplicate ids are fine (padded slots repeat the last client).
        """
        return self._fns.gather(self.buf, self.ibuf, self._ids(ids))

    def gather_one(self, client_id: int):
        """-> one client's snapshot as a model pytree."""
        return self._fns.gather_one(self.buf, self.ibuf, int(client_id))

    def scatter(self, ids: Sequence[int], flat_global):
        """Write one flat global row into every ``ids`` slot in place
        (donated).  Duplicate ids write the same row — harmless."""
        frow, irow = self._rows_of(flat_global)
        self.buf, self.ibuf = self._fns.scatter(
            self.buf, self.ibuf, self._ids(ids), frow, irow)

    def scatter_params(self, ids: Sequence[int], params):
        """Flatten ``params`` and scatter it into ``ids`` as ONE
        program; returns the flat row for callers tracking the current
        global row."""
        self.buf, self.ibuf, frow, irow = self._fns.scatter_params(
            self.buf, self.ibuf, self._ids(ids), params)
        return self._row_value(frow, irow)

    # -- merge + scatter (the async round-step tail) --------------------
    def merge_scatter(self, ids: Sequence[int], stacked_updates, coef,
                      params, *, use_kernel: bool = False,
                      interpret=None):
        """Fold one drained window into the global model and re-snapshot
        the merged clients.

        ``stacked_updates``: trained cohort pytree, leaves
        (len(ids), ...).  ``coef``: (len(ids)+1,) telescoped merge
        coefficients (``staleness_merge_coefficients`` order: global
        row 0 first) — zero entries (masked stragglers / padded rows)
        contribute exactly nothing.  ``params``: the current global
        model pytree.  ``use_kernel=True`` dispatches the merge through
        the folded Pallas fedagg kernel (interpret-mode on CPU,
        compiled on TPU) — the same ``fedagg_fold_pytree`` program the
        dict path's ``staleness_weighted_merge(use_kernel=True)`` runs.
        Returns ``(new_params, new_global_flat)``.

        The merge ALWAYS dispatches the standalone jitted program the
        dict reference runs (``_merge_folded_jnp`` or the fedagg
        kernel), then scatters through the fused flatten+scatter
        program.  Tracing the merge INSIDE the donated scatter program
        would let XLA re-fuse the reduction per buffer shape (FMA
        contraction differs across compilation units — and across
        buffer HEIGHTS, so a tiered/sharded store could never match
        the dense one).  Two dispatches buy histories that are
        bit-identical to the dict path and across residency layouts by
        construction.
        """
        tel = obs.TEL
        coef = jnp.asarray(np.asarray(coef, np.float32))
        with tel.span("store.merge", rows=len(ids), kernel=use_kernel):
            if use_kernel:
                interp = on_cpu() if interpret is None else bool(interpret)
                new_params = fedagg_fold_pytree(params, stacked_updates,
                                                coef, interpret=interp)
            else:
                new_params = _merge_folded_jnp(params, stacked_updates,
                                               coef)
        with tel.span("store.scatter", rows=len(ids)):
            row = self.scatter_params(ids, new_params)
        return new_params, row
