"""Device-resident flat client-state store — where client models LIVE.

The async runtime (PR 2) kept per-client model snapshots as a Python
``Dict[int, pytree]``: N full scattered copies of the model, re-stacked
leaf by leaf (``tree_map(jnp.stack)``) on every drained window before
the cohort could train.  The flatten-once ``(N, P)`` representation the
Pallas fedagg kernel already uses for aggregation is the natural home
for that state instead: ``ClientStateStore`` holds every client's
snapshot as one row of a single device-resident ``(N, P)`` f32 buffer
— plus, for models that carry non-float state (step counters, masks),
a sidecar ``(N, Pi)`` int32 segment — with the unflatten spec (per-leaf
segment/offset/shape/dtype views) cached once at construction.

* ``gather(ids)`` returns the stacked start-params pytree for a cohort
  (one device program: row gather + per-leaf slice/reshape/cast) — no
  per-leaf host stacking, no dict lookups.
* ``scatter(ids, flat_global)`` writes one global row into the merged
  clients' slots via ``buf.at[ids].set(...)`` under a jit that DONATES
  the buffers (donation is applied on accelerator backends; XLA CPU
  does not implement donation, so it is skipped there to avoid
  warnings), so the store updates in place instead of copying N*P
  floats per window.
* ``merge_scatter(ids, stacked_updates, coef, global_flat)`` is the
  fused tail of the async round step: staleness merge (global model as
  the implicit row 0, zero-coefficient rows masked to exact no-ops —
  the straggler-mask convention, which also makes padded rows free) +
  flatten of the new global row + scatter, ONE jitted buffer-donating
  program per padded cohort-size bucket.  ``use_kernel=True``
  dispatches the merge through the folded Pallas fedagg kernel
  (``fedagg_fold_pytree`` — interpret-mode on CPU, compiled on TPU),
  the SAME program the dict-of-pytrees reference's
  ``staleness_weighted_merge(use_kernel=True)`` runs, so kernel-path
  histories stay bit-identical between the two snapshot paths.

Donation contract: the store owns its buffers.  Callers must NOT hold
references into ``store.buffer``/``store.int_buffer`` across
``scatter``/``merge_scatter`` calls — on donating backends the old
buffer is invalidated in place.  ``gather``/``gather_one`` return
fresh arrays and are always safe.

Sharding: pass a 1-D client mesh to shard the row axis across devices
(rows padded to a mesh multiple via ``ClientShardingPlan`` — the extra
rows are never addressed).  Gather/merge/scatter then run as GSPMD
programs over the row-sharded buffers, composing with the sharded
engine's cohort padding.

Dtype note (segment layout): f32/bf16/f16 leaves live in the f32 row
segment (every bf16/f16 value is exactly representable in f32 — exact
round-trip).  bool and integer leaves of <= 32 bits live in the int32
sidecar segment: bool/int8/int16/int32/uint8/uint16 values embed
exactly in int32 (plain ``astype`` both ways); uint32 round-trips via
``lax.bitcast_convert_type`` (bit pattern preserved).  Leaves the
store cannot carry exactly — 64-bit ints, f64, complex — are rejected
at construction with ``TypeError``.

Quantized rows (``quant_bits=8``): the float segment is stored as a
shifted-scale int8 buffer plus a tiny per-leaf f32 scale/zero-point
sidecar (``(rows, 2L)`` for L float leaves — the int32 sidecar
machinery generalized to a third segment).  Writes quantize inside
``scatter``/``scatter_params``/``merge_scatter`` and reads dequantize
inside ``gather``/``gather_one`` as jitted programs per cohort bucket
— only cohort-sized ``(K, Pf)`` blocks ever exist in f32, the hot loop
never materializes an f32 ``(N, P)`` buffer.  The quantize and
dequantize math each live in ONE standalone compiled program shared by
every residency layout (the donated row writes are separate programs):
``dq = q*scale + zp`` is FMA-contractible, and XLA contracts
differently per compilation unit, so fusing it into buffer-shaped
programs would break cross-layout bit-identity — the PR 5
merge-dispatch lesson applied to quantization.  Server-side **error-feedback accumulators** (on by
default) keep each client's quantization residual ``x - dq(q(x))`` in
sparse host memory — it models state a real deployment keeps at the
client, so it is NOT counted as store bytes — and add it back before
the next quantization of that client's row, making the stored
snapshot unbiased over successive writes.  Contract: ``quant_bits=32``
(the default) is byte-for-byte the existing store path; quantized runs
are seeded-deterministic (dense/tiered/disk layouts stay bit-identical
to EACH OTHER — every quantize runs the same segment-min/max program)
but carry a gated convergence delta vs the f32 reference.
"""

from __future__ import annotations

import functools
from types import SimpleNamespace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import _merge_folded_jnp
from repro.kernels.ops import (dequantize_rows, dequantize_segment,
                               fedagg_fold_pytree, on_cpu, quantize_rows,
                               tree_spec)
from repro.obs import telemetry as obs

_FLOAT_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


def _leaf_kind(dtype) -> str:
    """Segment + conversion rule of one leaf dtype: "f" (f32 segment),
    "i" (int32 sidecar, value-exact astype), "u32" (int32 sidecar,
    bitcast).  Raises TypeError for dtypes with no exact carrier."""
    d = jnp.dtype(dtype)
    if d in [jnp.dtype(x) for x in _FLOAT_DTYPES]:
        return "f"
    if d == jnp.dtype(jnp.uint32):
        return "u32"
    if (np.issubdtype(d, np.integer) or d == np.dtype(bool)) \
            and d.itemsize <= 4:
        return "i"
    raise TypeError(
        f"ClientStateStore rows are f32 + int32 segments: leaf dtype "
        f"{dtype} does not round-trip exactly (float leaves up to f32 "
        "and bool/int leaves up to 32 bits only)")


def _segment_entries(spec):
    """tree_spec entries -> per-leaf (kind, segment offset, size, shape,
    dtype) with float and sidecar offsets accumulated independently.
    Returns (entries, float width Pf, sidecar width Pi)."""
    entries, f_off, i_off = [], 0, 0
    for _, size, shape, dtype in spec:
        kind = _leaf_kind(dtype)
        if kind == "f":
            entries.append((kind, f_off, size, shape, dtype))
            f_off += size
        else:
            entries.append((kind, i_off, size, shape, dtype))
            i_off += size
    return tuple(entries), f_off, i_off


def _to_rows(tree, entries):
    """Model pytree -> ((Pf,) f32 row, (Pi,) int32 row); either row may
    be zero-width."""
    leaves = jax.tree_util.tree_leaves(tree)
    f_parts, i_parts = [], []
    for l, (kind, _, _, _, _) in zip(leaves, entries):
        x = jnp.asarray(l)
        if kind == "f":
            f_parts.append(x.reshape(-1).astype(jnp.float32))
        elif kind == "i":
            i_parts.append(x.reshape(-1).astype(jnp.int32))
        else:
            i_parts.append(
                jax.lax.bitcast_convert_type(x, jnp.int32).reshape(-1))
    frow = (jnp.concatenate(f_parts) if f_parts
            else jnp.zeros((0,), jnp.float32))
    irow = (jnp.concatenate(i_parts) if i_parts
            else jnp.zeros((0,), jnp.int32))
    return frow, irow


def _leaf_from(seg, off, size, lead, kind, shape, dtype):
    x = seg[..., off:off + size].reshape(lead + shape)
    if kind == "u32":
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    return x.astype(dtype)


def _from_rows(frow, irow, treedef, entries):
    """((Pf,), (Pi,)) rows -> model pytree (exact per-leaf dtypes)."""
    outs = [_leaf_from(frow if kind == "f" else irow, off, size, (),
                       kind, shape, dtype)
            for kind, off, size, shape, dtype in entries]
    return jax.tree_util.tree_unflatten(treedef, outs)


def _from_stacked_rows(frows, irows, treedef, entries):
    """((K, Pf), (K, Pi)) row blocks -> stacked pytree, leaves (K, ...)."""
    k = frows.shape[0]
    outs = [_leaf_from(frows if kind == "f" else irows, off, size, (k,),
                       kind, shape, dtype)
            for kind, off, size, shape, dtype in entries]
    return jax.tree_util.tree_unflatten(treedef, outs)


def _float_segs(entries):
    """Static tuple of (offset, size) float-segment views in row order —
    the per-leaf layout ``quantize_rows``/``dequantize_segment`` slice."""
    return tuple((off, size) for kind, off, size, _, _ in entries
                 if kind == "f")


def _from_quant_rows(qrows, mrows, irows, lead, treedef, entries, fsegs):
    """Quantized row blocks -> pytree.  Float leaves dequantize straight
    into their leaf shapes (``dequantize_segment`` per leaf — no full
    f32 row is ever concatenated); sidecar leaves as in the f32 path."""
    outs, j = [], 0
    for kind, off, size, shape, dtype in entries:
        if kind == "f":
            x = dequantize_segment(qrows, mrows, fsegs, j)
            outs.append(x.reshape(lead + shape).astype(dtype))
            j += 1
        else:
            outs.append(_leaf_from(irows, off, size, lead, kind, shape,
                                   dtype))
    return jax.tree_util.tree_unflatten(treedef, outs)


@functools.lru_cache(maxsize=None)
def _programs(treedef, entries, donate: bool, fsegs=None):
    """Jitted store programs, cached per (tree structure, segment
    layout, donation mode, quantization layout) so every store over the
    same model family shares compiled code — a fresh store per run
    costs zero recompiles.

    Every program takes the store's row-segment buffers as ONE tuple
    ``bufs``: ``(fbuf, ibuf)`` for the f32 store, or ``(qbuf int8,
    mbuf f32 scale/zp, ibuf)`` when ``fsegs`` — the static float-leaf
    ``(offset, size)`` layout — selects the int8 quantized format.
    Donating the tuple donates every buffer in it, and the f32 traced
    computation is textually unchanged from the two-argument form, so
    ``quant_bits=32`` stays byte-for-byte the existing path."""

    quant = fsegs is not None

    def flatten_impl(tree):
        return _to_rows(tree, entries)

    def unflatten_impl(frow, irow):
        return _from_rows(frow, irow, treedef, entries)

    def quantize_impl(frow, ef):
        # One (Pf,) row + (K, Pf) per-client error-feedback residuals
        # -> per-client int8 rows, scale/zp meta, and the NEXT
        # residuals x - dq(q(x)).  Every reduction inside is a
        # per-segment min/max (order-independent), so the produced
        # bits cannot depend on K or on which program traced this.
        x = frow[None, :] + ef
        qrows, mrows = quantize_rows(x, fsegs)
        new_ef = x - dequantize_rows(qrows, mrows, fsegs)
        return qrows, mrows, new_ef

    def gather_impl(bufs, ids):
        # f32 stores only; quantized stores gather via read_rows ->
        # from_rows so dequantization has ONE compilation unit for
        # every residency layout (see the class gather docstring).
        fbuf, ibuf = bufs
        return _from_stacked_rows(fbuf[ids], ibuf[ids], treedef, entries)

    def gather_one_impl(bufs, i):
        if quant:
            qbuf, mbuf, ibuf = bufs
            return _from_quant_rows(qbuf[i], mbuf[i], ibuf[i], (),
                                    treedef, entries, fsegs)
        fbuf, ibuf = bufs
        return _from_rows(fbuf[i], ibuf[i], treedef, entries)

    def from_rows_impl(*blocks):
        # stacked pytree straight from materialized row blocks — the
        # tiered store's mixed hot/cold gather (rows assembled on host)
        if quant:
            qrows, mrows, irows = blocks
            return _from_quant_rows(qrows, mrows, irows,
                                    (qrows.shape[0],), treedef, entries,
                                    fsegs)
        frows, irows = blocks
        return _from_stacked_rows(frows, irows, treedef, entries)

    def read_rows_impl(bufs, ids):
        # raw row blocks (write-behind demotion reads these before the
        # slots are reused); never donated — it only reads.  Quantized
        # rows move between tiers as their stored int8/meta bits —
        # residency traffic never re-quantizes.
        return tuple(b[ids] for b in bufs)

    def write_rows_impl(bufs, ids, blocks):
        # per-row block write (host->device promotion)
        return tuple(b.at[ids].set(r) for b, r in zip(bufs, blocks))

    def scatter_impl(bufs, ids, frow, irow):
        # f32 stores only; quantized stores go quantize -> write_q so
        # the quantization math lives in ONE compilation unit (see the
        # class scatter docstring).
        fbuf, ibuf = bufs
        return fbuf.at[ids].set(frow), ibuf.at[ids].set(irow)

    def scatter_params_impl(bufs, ids, params):
        frow, irow = flatten_impl(params)
        fbuf, ibuf = bufs
        return ((fbuf.at[ids].set(frow), ibuf.at[ids].set(irow)),
                frow, irow)

    def write_q_impl(bufs, ids, qrows, mrows, irow):
        # quantized-store row write: per-client int8/meta blocks from
        # the standalone quantize program, one shared int32 sidecar row
        qbuf, mbuf, ibuf = bufs
        return (qbuf.at[ids].set(qrows), mbuf.at[ids].set(mrows),
                ibuf.at[ids].set(irow))

    def init_impl(params, rows):
        frow, irow = flatten_impl(params)
        if quant:
            qrow, mrow, _ = quantize_impl(
                frow, jnp.zeros((1,) + frow.shape, jnp.float32))
            return (jnp.tile(qrow, (rows, 1)), jnp.tile(mrow, (rows, 1)),
                    jnp.tile(irow[None], (rows, 1)))
        return (jnp.tile(frow[None], (rows, 1)),
                jnp.tile(irow[None], (rows, 1)))

    dk = dict(donate_argnums=(0,)) if donate else {}
    return SimpleNamespace(
        flatten=jax.jit(flatten_impl),
        unflatten=jax.jit(unflatten_impl),
        quantize=jax.jit(quantize_impl) if quant else None,
        gather=None if quant else jax.jit(gather_impl),
        gather_one=jax.jit(gather_one_impl),
        from_rows=jax.jit(from_rows_impl),
        read_rows=jax.jit(read_rows_impl),
        write_rows=jax.jit(write_rows_impl, **dk),
        scatter=None if quant else jax.jit(scatter_impl, **dk),
        scatter_params=None if quant else jax.jit(scatter_params_impl,
                                                  **dk),
        write_q=jax.jit(write_q_impl, **dk) if quant else None,
        init=jax.jit(init_impl, static_argnums=(1,)),
    )


class ClientStateStore:
    """All N client model snapshots as one device-resident (N, Pf) f32
    buffer plus an optional (N, Pi) int32 sidecar for non-float leaves.
    One instance per run; it owns the buffers (see the donation
    contract in the module docstring)."""

    def __init__(self, template_params, n_clients: int, *, mesh=None,
                 quant_bits: int = 32, error_feedback: bool = True):
        if n_clients < 1:
            raise ValueError(f"need at least one client, got {n_clients}")
        if int(quant_bits) not in (8, 32):
            raise ValueError(
                f"quant_bits must be 8 or 32, got {quant_bits}")
        treedef, spec, _ = tree_spec(template_params)
        self.treedef, self.spec = treedef, spec
        self.entries, self.p, self.pi = _segment_entries(spec)
        self.n = int(n_clients)
        self.mesh = mesh if (mesh is not None and int(mesh.size) > 1) \
            else None
        self.quant_bits = int(quant_bits)
        if self.quant_bits == 8:
            if self.mesh is not None:
                raise ValueError("quant_bits=8 does not compose with a "
                                 "sharded client mesh yet")
            if self.p == 0:
                raise ValueError("quant_bits=8 needs at least one float "
                                 "leaf to quantize")
        self._fsegs = _float_segs(self.entries) \
            if self.quant_bits == 8 else None
        # error feedback only means anything when quantizing; the
        # residual of an exact f32 write is identically zero.
        self.error_feedback = bool(error_feedback) and self.quant_bits == 8
        # client id -> (Pf,) f32 quantization residual, sparse (only
        # clients that have been written).  Models state a real
        # deployment keeps at the CLIENT, so bytes_by_tier() reports it
        # separately from the store's own row bytes.
        self._ef = {}
        self.rows = self._buffer_rows()
        # dense: every client's authoritative row lives on device.  The
        # tiered subclass overrides this tag ("tiered-host"/"tiered-disk").
        self.residency = "dense"
        # XLA CPU does not implement buffer donation — donating there
        # only emits warnings.  Donate on real accelerator backends.
        self._donate = jax.default_backend() != "cpu"
        obs.TEL.inc("store.donation_active" if self._donate
                    else "store.donation_skipped")
        self._fns = _programs(treedef, self.entries, self._donate,
                              self._fsegs)
        bufs = self._fns.init(template_params, self.rows)
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            rows_sharded = NamedSharding(self.mesh,
                                         P(self.mesh.axis_names[0]))
            bufs = tuple(jax.device_put(b, rows_sharded) for b in bufs)
        self.bufs = tuple(bufs)

    def _buffer_rows(self) -> int:
        """Height of the device-resident buffer (subclass hook: the
        tiered store allocates only its hot capacity)."""
        if self.mesh is not None:
            from repro.distributed.plan import ClientShardingPlan
            return ClientShardingPlan.for_cohort(self.n, self.mesh).padded_n
        return self.n

    @staticmethod
    def _ids(ids) -> jnp.ndarray:
        return jnp.asarray(np.asarray(ids, np.int32))

    def _rows_of(self, flat):
        """Public row value -> (frow, irow) pair.  Stores WITH a
        sidecar exchange ``(frow, irow)`` tuples; all-float stores keep
        the PR 4 plain-(P,) row convention."""
        if self.pi:
            frow, irow = flat
            return frow, irow
        return flat, jnp.zeros((0,), jnp.int32)

    def _row_value(self, frow, irow):
        return (frow, irow) if self.pi else frow

    # -- error-feedback residuals ---------------------------------------
    def _ef_block(self, ids):
        """(K, Pf) residual block for ``ids`` row-aligned with the
        scatter: each written client's last residual, zeros for clients
        never written (and everywhere when EF is off — the programs
        share one signature either way)."""
        k = len(ids)
        if not self.error_feedback or not self._ef:
            return jnp.zeros((k, self.p), jnp.float32)
        out = np.zeros((k, self.p), np.float32)
        for j, c in enumerate(ids):
            r = self._ef.get(int(c))
            if r is not None:
                out[j] = r
        return jnp.asarray(out)

    def _ef_update(self, ids, new_ef):
        """Store the (K, Pf) residuals the quantizing scatter returned.
        Duplicate ids carried identical inputs, so last-write-wins is
        exact."""
        if not self.error_feedback:
            return
        arr = np.asarray(new_ef, np.float32)
        for j, c in enumerate(ids):
            self._ef[int(c)] = np.array(arr[j])

    def ef_residual(self, client_id: int):
        """One client's current (Pf,) quantization residual, or None if
        that client has never been written (or EF is off)."""
        return self._ef.get(int(client_id))

    # -- byte accounting ------------------------------------------------
    @property
    def wire_bytes_per_update(self) -> int:
        """Modeled uplink bytes of ONE client update in this store's
        row format: int8 segment + f32 scale/zp meta + int32 sidecar
        when quantized, full-width f32 + sidecar otherwise."""
        if self.quant_bits == 8:
            return self.p + 8 * len(self._fsegs) + 4 * self.pi
        return 4 * self.p + 4 * self.pi

    def bytes_by_tier(self):
        """{"hot": device row bytes, "cold": spilled row bytes, "ef":
        error-feedback residual bytes} — ``ef`` is reported separately
        because it models client-side state, not store rows.  Also
        refreshes the ``store.bytes_hot``/``store.bytes_cold`` gauges."""
        out = {"hot": int(sum(b.nbytes for b in self.bufs)),
               "cold": self._cold_nbytes(),
               "ef": 4 * self.p * len(self._ef)}
        obs.TEL.gauge("store.bytes_hot", out["hot"])
        obs.TEL.gauge("store.bytes_cold", out["cold"])
        return out

    def _cold_nbytes(self) -> int:
        return 0  # dense store: everything is hot (tiered overrides)

    # -- flat <-> pytree views ------------------------------------------
    @property
    def buffer(self):
        """The primary (rows, Pf) row buffer — f32, or int8 when
        ``quant_bits=8``.  Read-only by convention — do not hold a
        reference across scatter/merge_scatter (donation)."""
        return self.bufs[0]

    @property
    def int_buffer(self):
        """The (rows, Pi) int32 sidecar (zero-width when the template
        has float leaves only).  Same donation contract as ``buffer``."""
        return self.bufs[-1]

    def flatten(self, params):
        """Model pytree -> flat row (one jitted concat): a (Pf,) f32
        array, or a ``(f32 row, int32 row)`` pair when the template has
        non-float leaves."""
        frow, irow = self._fns.flatten(params)
        return self._row_value(frow, irow)

    def unflatten(self, flat):
        """Flat row (``flatten``'s convention) -> model pytree with
        per-leaf shapes/dtypes."""
        frow, irow = self._rows_of(flat)
        return self._fns.unflatten(frow, irow)

    # -- gather / scatter -----------------------------------------------
    def gather(self, ids: Sequence[int]):
        """-> stacked start-params pytree, leaves (len(ids), ...).

        One device program per ids-length bucket (callers pad cohorts
        — the engine's pow2/mesh convention — to bound retraces).
        Duplicate ids are fine (padded slots repeat the last client).

        Quantized stores dequantize through the ``from_rows`` program
        for EVERY layout (dense, tiered hot, tiered mixed) — one
        compilation unit producing the f32 view, so gathered bits
        cannot depend on residency (``dq = q*scale + zp`` is
        FMA-contractible, and XLA may contract differently per
        compilation unit — the PR 5 merge-dispatch lesson applied to
        dequantization).
        """
        idl = self._ids(ids)
        if self.quant_bits == 8:
            return self._fns.from_rows(*self._fns.read_rows(self.bufs,
                                                            idl))
        return self._fns.gather(self.bufs, idl)

    def gather_one(self, client_id: int):
        """-> one client's snapshot as a model pytree."""
        return self._fns.gather_one(self.bufs, int(client_id))

    def _quantize_for(self, ids: Sequence[int], frow):
        """Quantize one global row per target client (error-feedback
        residual added back, fresh residual banked); returns the (K,)
        int8/meta row blocks to write.  The quantization ALWAYS runs
        the standalone ``quantize`` program — tracing it into a donated
        write would let XLA contract the dequantize FMA differently per
        buffer height, and the residuals (hence every later write)
        would diverge across residency layouts."""
        qrows, mrows, new_ef = self._fns.quantize(frow,
                                                  self._ef_block(ids))
        self._ef_update(ids, new_ef)
        return qrows, mrows

    def scatter(self, ids: Sequence[int], flat_global):
        """Write one flat global row into every ``ids`` slot in place
        (donated).  Duplicate ids write the same row — harmless (equal
        error-feedback inputs produce equal quantized rows)."""
        frow, irow = self._rows_of(flat_global)
        idl = self._ids(ids)
        if self.quant_bits == 8:
            qrows, mrows = self._quantize_for(ids, frow)
            self.bufs = self._fns.write_q(self.bufs, idl, qrows, mrows,
                                          irow)
        else:
            self.bufs = self._fns.scatter(self.bufs, idl, frow, irow)

    def scatter_params(self, ids: Sequence[int], params):
        """Flatten ``params`` and scatter it into ``ids``; returns the
        flat row for callers tracking the current global row (always
        the exact f32 row — quantization is internal to the buffers).
        The f32 store fuses flatten+scatter into one program; the
        quantized store dispatches flatten, quantize, write."""
        if self.quant_bits == 8:
            frow, irow = self._fns.flatten(params)
            self.scatter(ids, self._row_value(frow, irow))
            return self._row_value(frow, irow)
        self.bufs, frow, irow = self._fns.scatter_params(
            self.bufs, self._ids(ids), params)
        return self._row_value(frow, irow)

    # -- merge + scatter (the async round-step tail) --------------------
    def merge_scatter(self, ids: Sequence[int], stacked_updates, coef,
                      params, *, use_kernel: bool = False,
                      interpret=None):
        """Fold one drained window into the global model and re-snapshot
        the merged clients.

        ``stacked_updates``: trained cohort pytree, leaves
        (len(ids), ...).  ``coef``: (len(ids)+1,) telescoped merge
        coefficients (``staleness_merge_coefficients`` order: global
        row 0 first) — zero entries (masked stragglers / padded rows)
        contribute exactly nothing.  ``params``: the current global
        model pytree.  ``use_kernel=True`` dispatches the merge through
        the folded Pallas fedagg kernel (interpret-mode on CPU,
        compiled on TPU) — the same ``fedagg_fold_pytree`` program the
        dict path's ``staleness_weighted_merge(use_kernel=True)`` runs.
        Returns ``(new_params, new_global_flat)``.

        The merge ALWAYS dispatches the standalone jitted program the
        dict reference runs (``_merge_folded_jnp`` or the fedagg
        kernel), then scatters through the fused flatten+scatter
        program.  Tracing the merge INSIDE the donated scatter program
        would let XLA re-fuse the reduction per buffer shape (FMA
        contraction differs across compilation units — and across
        buffer HEIGHTS, so a tiered/sharded store could never match
        the dense one).  Two dispatches buy histories that are
        bit-identical to the dict path and across residency layouts by
        construction.
        """
        tel = obs.TEL
        coef = jnp.asarray(np.asarray(coef, np.float32))  # fedlint: disable=FED002 -- coef is the host numpy staleness-coefficient vector, packing not a device readback
        with tel.span("store.merge", rows=len(ids), kernel=use_kernel):
            if use_kernel:
                interp = on_cpu() if interpret is None else bool(interpret)
                new_params = fedagg_fold_pytree(params, stacked_updates,
                                                coef, interpret=interp)
            else:
                new_params = _merge_folded_jnp(params, stacked_updates,
                                               coef)
        with tel.span("store.scatter", rows=len(ids)):
            row = self.scatter_params(ids, new_params)
        return new_params, row


def wire_bytes(params, quant_bits: int = 32) -> int:
    """Modeled uplink bytes of ONE client update for ``params`` under
    the given row format — the store-free companion of
    ``ClientStateStore.wire_bytes_per_update`` (the dict-of-pytrees
    runners use it so ``meta["bytes_up"]`` is comparable across
    snapshot paths)."""
    _, spec, _ = tree_spec(params)
    entries, pf, pi = _segment_entries(spec)
    if int(quant_bits) == 8:
        n_float = sum(1 for kind, *_ in entries if kind == "f")
        return pf + 8 * n_float + 4 * pi
    return 4 * pf + 4 * pi
