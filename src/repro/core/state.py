"""Device-resident flat client-state store — where client models LIVE.

The async runtime (PR 2) kept per-client model snapshots as a Python
``Dict[int, pytree]``: N full scattered copies of the model, re-stacked
leaf by leaf (``tree_map(jnp.stack)``) on every drained window before
the cohort could train.  The flatten-once ``(N, P)`` representation the
Pallas fedagg kernel already uses for aggregation is the natural home
for that state instead: ``ClientStateStore`` holds every client's
snapshot as one row of a single device-resident ``(N, P)`` f32 buffer,
with the unflatten spec (per-leaf offset/size/shape/dtype views) cached
once at construction.

* ``gather(ids)`` returns the stacked start-params pytree for a cohort
  (one device program: row gather + per-leaf slice/reshape/cast) — no
  per-leaf host stacking, no dict lookups.
* ``scatter(ids, flat_global)`` writes one global row into the merged
  clients' slots via ``buf.at[ids].set(...)`` under a jit that DONATES
  the buffer (donation is applied on accelerator backends; XLA CPU
  does not implement donation, so it is skipped there to avoid
  warnings), so the store updates in place instead of copying N*P
  floats per window.
* ``merge_scatter(ids, stacked_updates, coef, global_flat)`` is the
  fused tail of the async round step: staleness merge (global model as
  the implicit row 0, zero-coefficient rows masked to exact no-ops —
  the straggler-mask convention, which also makes padded rows free) +
  flatten of the new global row + scatter, ONE jitted buffer-donating
  program per padded cohort-size bucket.

Donation contract: the store owns its buffer.  Callers must NOT hold
references into ``store.buffer`` across ``scatter``/``merge_scatter``
calls — on donating backends the old buffer is invalidated in place.
``gather``/``gather_one`` return fresh arrays and are always safe.

Sharding: pass a 1-D client mesh to shard the row axis across devices
(rows padded to a mesh multiple via ``ClientShardingPlan`` — the extra
rows are never addressed).  Gather/merge/scatter then run as GSPMD
programs over the row-sharded buffer, composing with the sharded
engine's cohort padding.

Dtype note: rows are f32; f32/bf16/f16 leaves round-trip exactly
(every bf16/f16 value is exactly representable in f32).  Integer /
f64 leaves are rejected at construction.
"""

from __future__ import annotations

import functools
from types import SimpleNamespace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import _merge_folded_jnp
from repro.kernels.ops import flatten_tree, tree_spec, unflatten_tree

_OK_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)


@functools.lru_cache(maxsize=None)
def _programs(treedef, spec, donate: bool):
    """Jitted store programs, cached per (tree structure, donation
    mode) so every store over the same model family shares compiled
    code — a fresh store per run costs zero recompiles."""

    def flatten_impl(tree):
        return flatten_tree(tree)[0]

    def unflatten_impl(flat):
        return unflatten_tree(flat, treedef, spec)

    def unflatten_stacked_impl(rows):
        k = rows.shape[0]
        outs = [rows[:, off:off + size].reshape((k,) + shape)
                .astype(dtype) for off, size, shape, dtype in spec]
        return jax.tree_util.tree_unflatten(treedef, outs)

    def gather_impl(buf, ids):
        return unflatten_stacked_impl(buf[ids])

    def gather_one_impl(buf, i):
        return unflatten_impl(buf[i])

    def scatter_impl(buf, ids, row):
        return buf.at[ids].set(row)

    def scatter_params_impl(buf, ids, params):
        row = flatten_impl(params)
        return buf.at[ids].set(row), row

    def merge_scatter_impl(buf, ids, stacked, coef, params):
        # the exact folded-merge program of the dict-of-pytrees path
        # (staleness_weighted_merge), fused with the flatten of the
        # new global row and the snapshot scatter — padded rows carry
        # coef 0 and are masked to exact no-ops.
        new_params = _merge_folded_jnp(params, stacked, coef)
        new_g = flatten_impl(new_params)
        return buf.at[ids].set(new_g), new_g, new_params

    def init_impl(params, rows):
        return jnp.tile(flatten_impl(params)[None], (rows, 1))

    dk = dict(donate_argnums=(0,)) if donate else {}
    return SimpleNamespace(
        flatten=jax.jit(flatten_impl),
        unflatten=jax.jit(unflatten_impl),
        gather=jax.jit(gather_impl),
        gather_one=jax.jit(gather_one_impl),
        scatter=jax.jit(scatter_impl, **dk),
        scatter_params=jax.jit(scatter_params_impl, **dk),
        merge_scatter=jax.jit(merge_scatter_impl, **dk),
        init=jax.jit(init_impl, static_argnums=(1,)),
    )


class ClientStateStore:
    """All N client model snapshots as one device-resident (N, P) f32
    buffer.  One instance per run; it owns the buffer (see the
    donation contract in the module docstring)."""

    def __init__(self, template_params, n_clients: int, *, mesh=None):
        if n_clients < 1:
            raise ValueError(f"need at least one client, got {n_clients}")
        treedef, spec, self.p = tree_spec(template_params)
        self.treedef, self.spec = treedef, spec
        for _, _, shape, dtype in spec:
            if jnp.dtype(dtype) not in [jnp.dtype(d) for d in _OK_DTYPES]:
                raise TypeError(
                    f"ClientStateStore rows are f32: leaf dtype {dtype} "
                    "does not round-trip exactly (float leaves only)")
        self.n = int(n_clients)
        self.mesh = mesh if (mesh is not None and int(mesh.size) > 1) \
            else None
        if self.mesh is not None:
            from repro.distributed.plan import ClientShardingPlan
            self.rows = ClientShardingPlan.for_cohort(
                self.n, self.mesh).padded_n
        else:
            self.rows = self.n
        # XLA CPU does not implement buffer donation — donating there
        # only emits warnings.  Donate on real accelerator backends.
        self._donate = jax.default_backend() != "cpu"
        self._fns = _programs(treedef, tuple(tuple(s) for s in spec),
                              self._donate)
        buf = self._fns.init(template_params, self.rows)
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            buf = jax.device_put(
                buf, NamedSharding(self.mesh, P(self.mesh.axis_names[0])))
        self.buf = buf

    @staticmethod
    def _ids(ids) -> jnp.ndarray:
        return jnp.asarray(np.asarray(ids, np.int32))

    # -- flat <-> pytree views ------------------------------------------
    @property
    def buffer(self):
        """The (rows, P) f32 buffer.  Read-only by convention — do not
        hold a reference across scatter/merge_scatter (donation)."""
        return self.buf

    def flatten(self, params):
        """Model pytree -> (P,) f32 row (one jitted concat)."""
        return self._fns.flatten(params)

    def unflatten(self, flat):
        """(P,) row -> model pytree with per-leaf shapes/dtypes."""
        return self._fns.unflatten(flat)

    # -- gather / scatter -----------------------------------------------
    def gather(self, ids: Sequence[int]):
        """-> stacked start-params pytree, leaves (len(ids), ...).

        One device program per ids-length bucket (callers pad cohorts
        — the engine's pow2/mesh convention — to bound retraces).
        Duplicate ids are fine (padded slots repeat the last client).
        """
        return self._fns.gather(self.buf, self._ids(ids))

    def gather_one(self, client_id: int):
        """-> one client's snapshot as a model pytree."""
        return self._fns.gather_one(self.buf, int(client_id))

    def scatter(self, ids: Sequence[int], flat_global):
        """Write one (P,) global row into every ``ids`` slot in place
        (donated).  Duplicate ids write the same row — harmless."""
        self.buf = self._fns.scatter(self.buf, self._ids(ids),
                                     flat_global)

    def scatter_params(self, ids: Sequence[int], params):
        """Flatten ``params`` and scatter it into ``ids`` as ONE
        program; returns the (P,) row for callers tracking the current
        global row."""
        self.buf, row = self._fns.scatter_params(self.buf,
                                                  self._ids(ids), params)
        return row

    # -- fused merge + scatter (the async round-step tail) --------------
    def merge_scatter(self, ids: Sequence[int], stacked_updates, coef,
                      params):
        """Fold one drained window into the global model and re-snapshot
        the merged clients, as ONE donated program.

        ``stacked_updates``: trained cohort pytree, leaves
        (len(ids), ...).  ``coef``: (len(ids)+1,) telescoped merge
        coefficients (``staleness_merge_coefficients`` order: global
        row 0 first) — zero entries (masked stragglers / padded rows)
        contribute exactly nothing.  ``params``: the current global
        model pytree.  Returns ``(new_params, new_global_flat)``.
        """
        coef = jnp.asarray(np.asarray(coef, np.float32))
        self.buf, new_g, new_params = self._fns.merge_scatter(
            self.buf, self._ids(ids), stacked_updates, coef, params)
        return new_params, new_g
