"""Server-side model aggregation (Alg. 1 line 8 / Alg. 2 last line).

Three layers:

* ``weighted_average_stacked`` — the engine hot path.  Takes a pytree
  whose leaves already carry a leading client axis (N, ...) plus a
  weight vector (N,), and reduces on device.  Zero-weight rows are
  masked out (fused straggler masking), so dropped clients never force
  a host-side re-pack of the buffer.  An optional per-row ``alphas``
  vector multiplies the weights (staleness discounting for the async
  runtime); a zero-alpha row is masked exactly like a zero weight.
  ``use_kernel=True`` routes through the pytree-native Pallas fedagg
  path (single flattened (N, P) kernel pass); otherwise a pure-jnp
  einsum-style reduction.
* ``staleness_weighted_merge`` — the async runtime's windowed merge:
  the exact batched equivalent of sequentially applying
  ``staleness_merge`` row by row, computed as ONE stacked reduction
  with the global model as an IMPLICIT row 0 (its telescoped
  coefficient multiplies the global leaves directly — no
  ``jnp.concatenate`` of a (K+1, ...) copy, no fresh ``np.ones``
  weight vector per window).
* ``aggregate_or_keep`` — ``weighted_average_stacked`` with the
  all-masked guard moved on device: a ``lax.cond`` returns the global
  params unchanged when every effective weight is zero, so the round
  step never syncs a weight sum back to the host.
* ``weighted_average`` — list-of-pytrees convenience wrapper kept for
  the looped reference implementations and external callers; it stacks
  then delegates.

``staleness_merge`` is FedAsync's two-model blend (the one-client
degenerate case of ``staleness_weighted_merge``).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _agg_jnp(stacked, w, a):
    eff = w * a
    wn = jnp.where(eff > 0.0, eff, 0.0)
    wn = wn / jnp.maximum(wn.sum(), 1e-30)

    def agg(leaf):
        wb = wn.reshape((-1,) + (1,) * (leaf.ndim - 1))
        u = jnp.where(wb > 0.0, leaf.astype(jnp.float32), 0.0)
        return jnp.sum(u * wb, axis=0).astype(leaf.dtype)
    return jax.tree_util.tree_map(agg, stacked)


def weighted_average_stacked(stacked, weights, *, alphas=None,
                             use_kernel: bool = False,
                             interpret: Optional[bool] = None):
    """Reduce a stacked update pytree (leaves (N, ...)) with weights (N,).

    sum_c eff_c * u_c / sum(eff) with eff_c = w_c * alpha_c
    (``alphas=None`` -> all ones).  Rows with eff_c <= 0 are masked to
    exactly zero before the reduction (straggler masking); if every
    effective weight is zero the result is an all-zeros pytree.
    """
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel:
        from repro.kernels import fedagg_pytree
        a = None if alphas is None else jnp.asarray(alphas, jnp.float32)
        return fedagg_pytree(stacked, w, alphas=a, interpret=interpret)
    a = (jnp.ones_like(w) if alphas is None
         else jnp.asarray(alphas, jnp.float32))
    return _agg_jnp(stacked, w, a)


@jax.jit
def _agg_or_keep_jnp(params, stacked, w, a):
    eff = w * a
    total = jnp.sum(jnp.where(eff > 0.0, eff, 0.0))

    def agg():
        # cast to the params leaves' dtypes so both cond branches carry
        # identical avals even when a trainer returns float-promoted
        # updates (astype is a no-op for matching dtypes)
        return jax.tree_util.tree_map(
            lambda p, m: m.astype(p.dtype), params,
            _agg_jnp(stacked, w, a))

    return jax.lax.cond(total > 0.0, agg, lambda: params)


def aggregate_or_keep(params, stacked, weights, *, alphas=None,
                      use_kernel: bool = False,
                      interpret: Optional[bool] = None):
    """``weighted_average_stacked`` that falls back to ``params`` when
    every effective weight is zero (the all-straggler round), decided
    ON DEVICE via ``lax.cond`` — no per-round host sync of the weight
    sum.  Leaf shapes/dtypes of ``params`` must match the per-row
    shapes of ``stacked`` (the engine round contract)."""
    w = jnp.asarray(weights, jnp.float32)
    a = (jnp.ones_like(w) if alphas is None
         else jnp.asarray(alphas, jnp.float32))
    if use_kernel:
        agg = weighted_average_stacked(stacked, w, alphas=a,
                                       use_kernel=True, interpret=interpret)
        any_live = jnp.sum(jnp.where(w * a > 0.0, w * a, 0.0)) > 0.0
        return jax.tree_util.tree_map(
            lambda p, m: jnp.where(any_live, m.astype(p.dtype), p),
            params, agg)
    return _agg_or_keep_jnp(params, stacked, w, a)


def weighted_average(param_list: Sequence, sizes: Sequence[float],
                     use_kernel: bool = False,
                     interpret: Optional[bool] = None):
    """FedAvg: sum_c w_c * s_c / sum(s) over a list of update pytrees."""
    if len(param_list) == 0:
        raise ValueError("no client updates to aggregate")
    w = jnp.asarray(np.asarray(sizes, np.float32))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *param_list)
    return weighted_average_stacked(stacked, w, use_kernel=use_kernel,
                                    interpret=interpret)


def staleness_merge(global_params, client_params, alpha_t: float):
    """FedAsync: w <- (1-a) w + a w_c."""
    return jax.tree_util.tree_map(
        lambda g, c: ((1 - alpha_t) * g.astype(jnp.float32)
                      + alpha_t * c.astype(jnp.float32)).astype(g.dtype),
        global_params, client_params)


def staleness_merge_coefficients(alphas) -> np.ndarray:
    """Row coefficients of the fused window merge.

    Sequentially applying ``staleness_merge`` with alphas a_1..a_K
    (row order = merge order) telescopes to the convex combination

        w <- prod_i (1-a_i) * w  +  sum_i a_i * prod_{j>i} (1-a_j) * w_i

    Returns the (K+1,) coefficient vector [global, row_1..row_K]; the
    entries sum to exactly 1 (up to fp), so the normalized stacked
    reduction reproduces the sequential merge in one pass.
    """
    a = np.asarray(alphas, np.float64).reshape(-1)
    one_minus = 1.0 - a
    # suffix[i] = prod_{j>i} (1-a_j); suffix[K-1] = 1
    suffix = np.ones_like(a)
    if a.size > 1:
        suffix[:-1] = np.cumprod(one_minus[::-1])[::-1][1:]
    coef = a * suffix
    g = float(np.prod(one_minus)) if a.size else 1.0
    return np.concatenate([[g], coef]).astype(np.float32)


@jax.jit
def _merge_folded_jnp(global_params, stacked, coef):
    """Folded window merge: coef (K+1,) row coefficients with the
    global model as the IMPLICIT row 0.  The exact per-leaf ops of
    ``_agg_jnp`` with the row-0 term pulled out of the stacked
    reduction — zero-coefficient rows are masked to exactly zero
    BEFORE the sum, so nonfinite garbage in masked rows (and the
    zero-padded rows of the store's fused round step) contributes
    nothing."""
    c = jnp.where(coef > 0.0, coef, 0.0)
    c = c / jnp.maximum(c.sum(), 1e-30)
    cr = c[1:]

    def merge(g, leaf):
        cb = cr.reshape((-1,) + (1,) * (leaf.ndim - 1))
        u = jnp.where(cb > 0.0, leaf.astype(jnp.float32), 0.0)
        g_term = jnp.where(c[0] > 0.0,
                           c[0] * g.astype(jnp.float32), 0.0)
        return (g_term + jnp.sum(u * cb, axis=0)).astype(g.dtype)

    return jax.tree_util.tree_map(merge, global_params, stacked)


def staleness_weighted_merge(global_params, stacked, alphas, *,
                             use_kernel: bool = False,
                             interpret: Optional[bool] = None):
    """Merge a whole aggregation window into the global model at once.

    ``stacked`` holds the window's client models with a leading row axis
    (K, ...); ``alphas`` are the per-row staleness weights
    a_i = alpha * (s_i + 1)^-a in merge order.  The result is the same
    convex combination a sequential ``staleness_merge`` fold would
    produce (up to float reassociation), computed as ONE stacked
    reduction with the global model as an IMPLICIT row 0: its
    telescoped coefficient multiplies the global leaves directly, so
    no (K+1, ...) copy is materialized and no per-window ``np.ones``
    weight vector is allocated.  Zero-alpha rows (masked stragglers)
    contribute exactly nothing.

    ``use_kernel=True`` routes through the folded Pallas fedagg kernel
    (``fedagg_fold_pytree``): the same implicit-row-0 formulation on
    the flattened (K, P) buffer — no (K+1, ...) concatenated copy
    there either.  The kernel runs interpret-mode on CPU and compiled
    on TPU; the store-backed fused window step dispatches the SAME
    program on the same flattened buffer, so kernel-path histories are
    bit-identical between the dict and store snapshot paths.
    """
    coef = staleness_merge_coefficients(alphas)
    if use_kernel:
        from repro.kernels import fedagg_fold_pytree
        return fedagg_fold_pytree(global_params, stacked,
                                  jnp.asarray(coef), interpret=interpret)
    return _merge_folded_jnp(global_params, stacked, jnp.asarray(coef))
