"""Server-side model aggregation (Alg. 1 line 8 / Alg. 2 last line).

``weighted_average`` stacks client updates and reduces with either plain
jnp einsum or the fused Pallas fedagg kernel (TPU hot path; interpret
mode on CPU).  ``staleness_merge`` is FedAsync's two-model blend.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def weighted_average(param_list: Sequence, sizes: Sequence[float],
                     use_kernel: bool = False):
    """FedAvg: sum_c w_c * s_c / sum(s)."""
    if len(param_list) == 0:
        raise ValueError("no client updates to aggregate")
    w = jnp.asarray(np.asarray(sizes, np.float32))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *param_list)
    if use_kernel:
        from repro.kernels import fedagg_pytree
        return fedagg_pytree(stacked, w)
    wn = w / jnp.maximum(w.sum(), 1e-30)
    def agg(leaf):
        wb = wn.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return jnp.sum(leaf.astype(jnp.float32) * wb, axis=0).astype(leaf.dtype)
    return jax.tree_util.tree_map(agg, stacked)


def staleness_merge(global_params, client_params, alpha_t: float):
    """FedAsync: w <- (1-a) w + a w_c."""
    return jax.tree_util.tree_map(
        lambda g, c: ((1 - alpha_t) * g.astype(jnp.float32)
                      + alpha_t * c.astype(jnp.float32)).astype(g.dtype),
        global_params, client_params)
