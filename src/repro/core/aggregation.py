"""Server-side model aggregation (Alg. 1 line 8 / Alg. 2 last line).

Two layers:

* ``weighted_average_stacked`` — the engine hot path.  Takes a pytree
  whose leaves already carry a leading client axis (N, ...) plus a
  weight vector (N,), and reduces on device.  Zero-weight rows are
  masked out (fused straggler masking), so dropped clients never force
  a host-side re-pack of the buffer.  ``use_kernel=True`` routes
  through the pytree-native Pallas fedagg path (single flattened
  (N, P) kernel pass); otherwise a pure-jnp einsum-style reduction.
* ``weighted_average`` — list-of-pytrees convenience wrapper kept for
  the looped reference implementations and external callers; it stacks
  then delegates.

``staleness_merge`` is FedAsync's two-model blend.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _agg_jnp(stacked, w):
    wn = w / jnp.maximum(w.sum(), 1e-30)

    def agg(leaf):
        wb = wn.reshape((-1,) + (1,) * (leaf.ndim - 1))
        u = jnp.where(wb > 0.0, leaf.astype(jnp.float32), 0.0)
        return jnp.sum(u * wb, axis=0).astype(leaf.dtype)
    return jax.tree_util.tree_map(agg, stacked)


def weighted_average_stacked(stacked, weights, *, use_kernel: bool = False,
                             interpret: Optional[bool] = None):
    """Reduce a stacked update pytree (leaves (N, ...)) with weights (N,).

    sum_c w_c * u_c / sum(w).  Rows with w_c == 0 are masked to exactly
    zero before the reduction (straggler masking); if every weight is
    zero the result is an all-zeros pytree.
    """
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel:
        from repro.kernels import fedagg_pytree
        return fedagg_pytree(stacked, w, interpret=interpret)
    return _agg_jnp(stacked, w)


def weighted_average(param_list: Sequence, sizes: Sequence[float],
                     use_kernel: bool = False,
                     interpret: Optional[bool] = None):
    """FedAvg: sum_c w_c * s_c / sum(s) over a list of update pytrees."""
    if len(param_list) == 0:
        raise ValueError("no client updates to aggregate")
    w = jnp.asarray(np.asarray(sizes, np.float32))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *param_list)
    return weighted_average_stacked(stacked, w, use_kernel=use_kernel,
                                    interpret=interpret)


def staleness_merge(global_params, client_params, alpha_t: float):
    """FedAsync: w <- (1-a) w + a w_c."""
    return jax.tree_util.tree_map(
        lambda g, c: ((1 - alpha_t) * g.astype(jnp.float32)
                      + alpha_t * c.astype(jnp.float32)).astype(g.dtype),
        global_params, client_params)
