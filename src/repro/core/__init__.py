from repro.core.tiering import tiering, update_avg_time, evaluate_client
from repro.core.selection import cstt, tier_timeouts, move_tier, select_from_tier
from repro.core.aggregation import (aggregate_or_keep,
                                    weighted_average,
                                    weighted_average_stacked,
                                    staleness_merge,
                                    staleness_weighted_merge)
from repro.core.engine import BatchedClientEngine, make_engine
from repro.core.residency import TieredClientStateStore
from repro.core.state import ClientStateStore
from repro.core.scheduler import run_feddct
from repro.core.baselines import (run_fedavg, run_tifl, run_fedasync,
                                  run_fedasync_sequential, run_fedbuff,
                                  run_feddct_async, run_fedprox,
                                  run_method)

__all__ = [
    "tiering", "update_avg_time", "evaluate_client",
    "cstt", "tier_timeouts", "move_tier", "select_from_tier",
    "aggregate_or_keep", "weighted_average", "weighted_average_stacked",
    "staleness_merge", "staleness_weighted_merge",
    "BatchedClientEngine", "ClientStateStore", "TieredClientStateStore",
    "make_engine",
    "run_feddct", "run_fedavg", "run_tifl", "run_fedasync",
    "run_fedasync_sequential", "run_fedbuff", "run_feddct_async",
    "run_fedprox", "run_method",
]
