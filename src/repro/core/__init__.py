from repro.core.aggregation import (aggregate_or_keep,
                                    staleness_merge,
                                    staleness_weighted_merge,
                                    weighted_average,
                                    weighted_average_stacked)
from repro.core.baselines import (run_fedasync, run_fedasync_sequential,
                                  run_fedavg, run_fedbuff,
                                  run_feddct_async, run_fedprox,
                                  run_method, run_tifl)
from repro.core.engine import BatchedClientEngine, make_engine
from repro.core.residency import TieredClientStateStore
from repro.core.scheduler import run_feddct
from repro.core.selection import cstt, move_tier, select_from_tier, tier_timeouts
from repro.core.state import ClientStateStore
from repro.core.tiering import evaluate_client, tiering, update_avg_time

__all__ = [
    "tiering", "update_avg_time", "evaluate_client",
    "cstt", "tier_timeouts", "move_tier", "select_from_tier",
    "aggregate_or_keep", "weighted_average", "weighted_average_stacked",
    "staleness_merge", "staleness_weighted_merge",
    "BatchedClientEngine", "ClientStateStore", "TieredClientStateStore",
    "make_engine",
    "run_feddct", "run_fedavg", "run_tifl", "run_fedasync",
    "run_fedasync_sequential", "run_fedbuff", "run_feddct_async",
    "run_fedprox", "run_method",
]
