"""FedDCT training loop (paper Alg. 2) over a virtual clock.

Round flow:
  1. Tier the currently-available clients on their running-average
     times (Alg. 3 — dynamic: re-split every round).
  2. CSTT (Alg. 4): move the tier pointer by the accuracy delta (Eq. 3),
     select tau low-participation clients from every tier 1..t (Eq. 4 as
     stated in the text), compute per-tier timeouts (Eq. 7).
  3. Clients train for real (JAX); their virtual cost comes from the
     wireless model.  A client whose time st >= D_max of its tier is a
     straggler: its update is dropped and it enters the parallel
     re-evaluation lane for kappa rounds (Alg. 2 "Async:" line).
     Survivors train as ONE batched vmapped step via the execution
     engine (core/engine.py) — virtual stragglers are known before
     training, so the cohort is trimmed first and the whole round is a
     single device program.
  4. Aggregate survivors weighted by sample count, on device — the
     all-masked guard is a device-side ``lax.cond`` inside
     ``engine.train_round`` (no per-round host sync of the weight
     sum); clock advances by Eq. 5/6: D = max over used tiers of
     min(max(st in tier), D_max^t, Ω).
  5. Clients whose evaluation lane finished (virtual time passed) rejoin
     with their refreshed average time.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.config.base import FLConfig
from repro.core.engine import make_engine
from repro.core.selection import cstt
from repro.core.tiering import evaluate_client, tiering, update_avg_time
from repro.fl.metrics import RunHistory
from repro.obs import flstats
from repro.obs import telemetry as obs


def run_feddct(trainer, network, fl: FLConfig, *, use_kernel_agg: bool = False,
               engine: str = "batched", verbose: bool = False,
               eval_every: int = 1, mesh=None) -> RunHistory:
    rng = np.random.default_rng(fl.seed + 7)
    tel = obs.TEL
    run_span = tel.span("run", method="feddct").start()
    hist = RunHistory(method="feddct", arch=trainer.cfg.arch_id,
                      meta={"mu": fl.mu, "primary_frac": fl.primary_frac,
                            "beta": fl.beta, "kappa": fl.kappa,
                            "omega": fl.omega, "tau": fl.tau,
                            "n_tiers": fl.n_tiers, "engine": engine,
                            "kernel_agg": use_kernel_agg,
                            "mesh_devices": (int(mesh.size)
                                             if mesh is not None else 1)})
    eng = make_engine(trainer, use_kernel_agg=use_kernel_agg, engine=engine,
                      mesh=mesh)
    params = trainer.init_params(fl.seed)
    clock = 0.0

    # ---- initial kappa-round evaluation of every client (parallel) ----
    at: Dict[int, float] = {}
    ct: Dict[int, int] = {}
    setup_times = []
    for c in range(fl.n_clients):
        t_avg, spent = evaluate_client(network, c, rnd=0, kappa=fl.kappa,
                                       omega=fl.omega)
        at[c] = t_avg
        ct[c] = 0
        setup_times.append(spent)
    clock += max(setup_times)               # all clients evaluate in parallel

    # straggler re-evaluation lane: client -> (rejoin_time, new_at)
    eval_lane: Dict[int, tuple] = {}
    t_ptr = 1
    # Alg. 4 compares v_r (accuracy of the current global model) with
    # v_{r-1}.  We evaluate once per round, after aggregation; that value
    # is v_r for the next round's tier move.
    v_curr = 0.0        # v_{r-1}: accuracy of the model entering this round
    v_prev = 0.0        # v_{r-2}
    m = max(fl.n_clients // fl.n_tiers, 1)

    for rnd in range(1, fl.rounds + 1):
        tel.set_virtual_time(clock)
        # ---- rejoin clients whose re-evaluation completed --------------
        for c in [c for c, (tr, _) in eval_lane.items() if tr <= clock]:
            at[c] = eval_lane.pop(c)[1]

        avail_at = {c: v for c, v in at.items() if c not in eval_lane}
        sel_span = tel.span("round.select", avail=len(avail_at)).start()
        tiers = tiering(avail_at, m)
        if not tiers:
            sel_span.end()
            break

        selected, d_max, t_ptr = cstt(
            t_ptr, v_prev, v_curr, tiers, avail_at, ct, fl.tau, fl.beta,
            fl.omega, rng)
        flstats.record_tiering(tiers, thresholds=d_max,
                               population=fl.n_clients)
        flstats.record_selection(selected)

        # ---- virtual delays decide survivors BEFORE any training ------
        survivors: List[int] = []
        times_per_tier: Dict[int, List[float]] = {}
        n_straggle = 0
        sts = network.delays([c for c, _ in selected], rnd)
        for (c, k), st in zip(selected, sts):
            times_per_tier.setdefault(k, []).append(min(st, d_max[k]))
            flstats.record_response(k + 1, float(st), d_max[k],
                                    timed_out=st >= d_max[k])
            if st >= d_max[k]:
                # straggler: drop update, enter evaluation lane
                n_straggle += 1
                flstats.record_straggler("dropped", tier=k + 1)
                new_at, spent = evaluate_client(network, c, rnd, fl.kappa,
                                                fl.omega)
                eval_lane[c] = (clock + spent, new_at)
                continue
            survivors.append(c)
            at[c] = update_avg_time(at[c], ct[c], st)
            ct[c] += 1
        sel_span.end()
        if n_straggle:
            tel.inc("stragglers.dropped", n_straggle)

        # ---- one batched device program for the whole cohort ----------
        params = eng.train_round(params, survivors, rnd)

        # Eq. 5/6 round duration
        d_round = 0.0
        for k, ts_k in times_per_tier.items():
            d_round = max(d_round, min(max(ts_k), d_max[k], fl.omega))
        clock += d_round

        if rnd % eval_every == 0:
            with tel.span("eval"):
                v_now = trainer.evaluate(params)
            hist.record(time=clock, rnd=rnd, acc=v_now, tier=t_ptr,
                        n_selected=len(selected), n_stragglers=n_straggle)
            v_prev, v_curr = v_curr, v_now
            if verbose:
                print(f"[feddct] r={rnd:4d} t={clock:9.1f}s tier={t_ptr} "
                      f"acc={v_now:.4f} sel={len(selected)} str={n_straggle}")
            if fl.target_accuracy and v_now >= fl.target_accuracy:
                break
    run_span.end()
    tel.summarize_into(hist.meta)
    return hist
