"""Pytree checkpointing to .npz (atomic, step-indexed, pure numpy).

Pytrees are flattened with ``jax.tree_util`` path strings as keys so any
nested dict/tuple/list of arrays round-trips, including optimizer state
and FL server state.  Scalars/ints are stored as 0-d arrays.
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".npz")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    meta = {"step": step, "n_leaves": len(leaves)}
    if metadata:
        meta.update(metadata)
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    return path


def load_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes must match)."""
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for a, b in zip(leaves, restored):
        if tuple(np.shape(a)) != tuple(b.shape):
            raise ValueError(f"shape mismatch: {np.shape(a)} vs {b.shape}")
    return jax.tree_util.tree_unflatten(treedef, restored)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
