"""xLSTM 350M — alternating sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0: xLSTM blocks carry their own up-projection (proj_factor)."""

from repro.config.base import ModelConfig, register_arch


@register_arch("xlstm-350m")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        activation="gelu",
        ssm_state=0,
        slstm_every=2,           # every 2nd block is sLSTM (alternating)
        proj_factor=2.0,
        citation="arXiv:2405.04517",
    )
