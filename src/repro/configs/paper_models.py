"""The paper's own FL workloads (§5.1): CNNs for MNIST / Fashion-MNIST
and ResNet8 for CIFAR-10."""

from repro.config.base import ModelConfig, register_arch


@register_arch("cnn-mnist")
def cnn_mnist() -> ModelConfig:
    # two conv layers 32/64 + 2x2 maxpool + FC 512 -> 10
    return ModelConfig(
        arch_id="cnn-mnist", family="cnn",
        cnn_channels=(32, 64), cnn_fc=(512, 10),
        input_hw=(28, 28, 1), n_classes=10,
        citation="FedDCT §5.1",
    )


@register_arch("cnn-fmnist")
def cnn_fmnist() -> ModelConfig:
    # two conv layers 32/64 + 2x2 maxpool + FC 128 -> 10
    return ModelConfig(
        arch_id="cnn-fmnist", family="cnn",
        cnn_channels=(32, 64), cnn_fc=(128, 10),
        input_hw=(28, 28, 1), n_classes=10,
        citation="FedDCT §5.1",
    )


@register_arch("resnet8-cifar10")
def resnet8() -> ModelConfig:
    return ModelConfig(
        arch_id="resnet8-cifar10", family="cnn",
        cnn_channels=(16, 32, 64), cnn_fc=(10,),
        input_hw=(32, 32, 3), n_classes=10, resnet=True,
        citation="FedDCT §5.1 / arXiv:2204.13399",
    )
