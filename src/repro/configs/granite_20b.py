"""IBM Granite 20B (code) — llama-arch dense, MQA (kv=1) [arXiv:2405.04324]."""

from repro.config.base import ModelConfig, register_arch


@register_arch("granite-20b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-20b",
        family="dense",
        num_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,            # MQA
        d_ff=24576,
        vocab_size=49152,
        activation="gelu",       # granite-20b-code uses gelu MLP
        citation="arXiv:2405.04324",
    )
