"""Hymba 1.5B — hybrid: parallel attention + mamba heads [arXiv:2411.13676]."""

from repro.config.base import ModelConfig, register_arch


@register_arch("hymba-1.5b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        activation="swiglu",
        ssm_state=16,
        ssm_expand=2,
        hybrid_parallel=True,
        sliding_window=1024,     # hymba uses SWA in most layers
        citation="arXiv:2411.13676",
    )
