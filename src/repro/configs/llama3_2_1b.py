"""Llama 3.2 1B — small llama3 dense GQA [hf:meta-llama/Llama-3.2-1B]."""

from repro.config.base import ModelConfig, register_arch


@register_arch("llama3.2-1b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama3.2-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        activation="swiglu",
        rope_theta=500_000.0,
        citation="hf:meta-llama/Llama-3.2-1B",
    )
