"""HuBERT X-Large — encoder-only audio transformer [arXiv:2106.07447].

Backbone only: the conv/mel frontend is a stub — ``input_specs`` feeds
precomputed frame embeddings of shape (batch, frames, d_model).
Vocab 504 = HuBERT's k-means target codebook size (masked-prediction head).
"""

from repro.config.base import ModelConfig, register_arch


@register_arch("hubert-xlarge")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,           # full MHA (GQA kv=16)
        d_ff=5120,
        vocab_size=504,
        activation="gelu",
        causal=False,            # bidirectional encoder
        frontend="audio_frames",
        citation="arXiv:2106.07447",
    )
