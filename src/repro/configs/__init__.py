"""Registers all selectable architectures (``--arch <id>``)."""

from repro.configs import (  # noqa: F401
    granite_20b,
    nemotron_4_340b,
    phi4_mini_3_8b,
    llama3_2_1b,
    mixtral_8x7b,
    hubert_xlarge,
    hymba_1_5b,
    arctic_480b,
    xlstm_350m,
    chameleon_34b,
    paper_models,
)
from repro.configs.shapes import INPUT_SHAPES  # noqa: F401
