"""Registers all selectable architectures (``--arch <id>``)."""

from repro.configs import (  # noqa: F401
    arctic_480b,
    chameleon_34b,
    granite_20b,
    hubert_xlarge,
    hymba_1_5b,
    llama3_2_1b,
    mixtral_8x7b,
    nemotron_4_340b,
    paper_models,
    phi4_mini_3_8b,
    xlstm_350m,
)
from repro.configs.shapes import INPUT_SHAPES  # noqa: F401
