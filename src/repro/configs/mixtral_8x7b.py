"""Mixtral 8x7B — MoE 8 experts top-2, GQA, sliding window [arXiv:2401.04088]."""

from repro.config.base import ModelConfig, register_arch


@register_arch("mixtral-8x7b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        activation="swiglu",
        n_experts=8,
        top_k=2,
        sliding_window=4096,
        citation="arXiv:2401.04088",
    )
