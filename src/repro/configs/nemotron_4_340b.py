"""Nemotron-4 340B — dense GQA, squared-ReLU MLP [arXiv:2402.16819]."""

from repro.config.base import ModelConfig, register_arch


@register_arch("nemotron-4-340b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="nemotron-4-340b",
        family="dense",
        num_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        d_ff=73728,
        vocab_size=256000,
        activation="squared_relu",
        citation="arXiv:2402.16819",
    )
