"""Snowflake Arctic 480B — 128-expert top-2 MoE + dense residual
[hf:Snowflake/snowflake-arctic-base]."""

from repro.config.base import ModelConfig, register_arch


@register_arch("arctic-480b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        activation="swiglu",
        n_experts=128,
        top_k=2,
        moe_dense_residual=True,   # dense FFN residual beside the MoE
        moe_dense_ff=4864,
        citation="hf:Snowflake/snowflake-arctic-base",
    )
