"""Assigned input shapes (re-exported from config.base for convenience)."""

from repro.config.base import INPUT_SHAPES, InputShape  # noqa: F401
