"""Chameleon 34B — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

Backbone only: the VQ-GAN image tokenizer is a stub — images arrive as
token ids inside the unified vocab (65536 includes 8192 VQ codes)."""

from repro.config.base import ModelConfig, register_arch


@register_arch("chameleon-34b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="chameleon-34b",
        family="vlm",
        num_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=65536,
        activation="swiglu",
        frontend="vq_patches",
        image_tokens=1024,
        citation="arXiv:2405.09818",
    )
