"""Phi-4-mini 3.8B — dense, RoPE + SwiGLU + GQA [arXiv:2412.08905]."""

from repro.config.base import ModelConfig, register_arch


@register_arch("phi4-mini-3.8b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi4-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=200064,
        activation="swiglu",
        citation="arXiv:2412.08905",
    )
