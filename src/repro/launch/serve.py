"""Batched decode server driver (reduced configs on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --batch 4 --prompt-len 32 --gen 32

Prefills a batch of token prompts, then serves batched single-token
decode steps with the ring-buffer KV / SSM caches — the same serve_step
the decode dry-run shapes lower.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch
from repro.models import decode_step, init_decode_state, init_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: nothing to decode")
    params = init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    cache_len = args.prompt_len + args.gen
    state = init_decode_state(cfg, args.batch, cache_len, dtype=jnp.float32)
    dstep = jax.jit(lambda p, s, t: decode_step(cfg, p, s, t))

    # prefill via repeated decode steps (cache-exact; fine at small scale)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, state = dstep(params, state, prompts[:, i:i + 1])
    prefill_t = time.time() - t0

    out_tokens = []
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, state = dstep(params, state, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    decode_t = time.time() - t0
    toks = np.stack(out_tokens, 1)
    print(f"[serve] {cfg.arch_id}: prefill {args.prompt_len} toks in "
          f"{prefill_t:.2f}s; decoded {args.gen} x{args.batch} in "
          f"{decode_t:.2f}s ({args.gen*args.batch/max(decode_t,1e-9):.1f} tok/s)")
    print(f"[serve] sample continuation ids: {toks[0][:16].tolist()}")
    return toks


if __name__ == "__main__":
    main()
