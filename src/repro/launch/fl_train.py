"""FedDCT over any assigned architecture — the paper's scheduler driving
LM clients (the datacenter embodiment from DESIGN.md §2).

    PYTHONPATH=src python -m repro.launch.fl_train --arch llama3.2-1b \
        --method feddct --rounds 20 --clients 10 --mu 0.2

Each FL client's local step is the same train_step the dry-run lowers;
on CPU the reduced config is used so rounds are fast.  The wireless
delay/failure model supplies virtual time exactly as for the CNN runs.
"""

from __future__ import annotations

import argparse

from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.network import WirelessNetwork


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--method", default="feddct",
                    choices=["feddct", "fedavg", "tifl", "fedasync",
                             "fedprox", "fedbuff", "feddct_async"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--tiers", type=int, default=5)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--mu", type=float, default=0.0)
    ap.add_argument("--primary-frac", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "looped"],
                    help="batched = vmapped multi-client engine; "
                         "looped = per-client reference path")
    ap.add_argument("--kernel-agg", action="store_true",
                    help="aggregate through the Pallas fedagg pytree path")
    ap.add_argument("--window", type=int, default=0,
                    help="async aggregation window: merge up to K "
                         "completions per event drain (fedasync/fedbuff; "
                         "0 = one-at-a-time FedAsync)")
    ap.add_argument("--window-secs", type=float, default=0.0,
                    help="async aggregation window in virtual seconds "
                         "(fedasync/fedbuff; 0 = no time window)")
    ap.add_argument("--no-store", action="store_true",
                    help="async methods only: keep client snapshots as "
                         "a dict of pytrees instead of the "
                         "device-resident flat ClientStateStore "
                         "(reference path, bit-identical histories)")
    ap.add_argument("--hot-rows", type=int, default=0,
                    help="async methods only: tiered client-state "
                         "residency — keep only this many client rows "
                         "on device (hot tier) and the rest in pinned "
                         "host memory, with EventQueue-driven prefetch "
                         "(0 = dense, every row on device; histories "
                         "are bit-identical at any capacity)")
    ap.add_argument("--cold-dir", default=None,
                    help="with --hot-rows: spill the cold tier to "
                         "ckpt-chunk files under this directory "
                         "instead of pinned host memory")
    ap.add_argument("--quant-bits", type=int, default=32,
                    choices=[8, 32],
                    help="async methods only: client-state row format. "
                         "32 = the byte-for-byte f32 store path; 8 = "
                         "int8 quantized rows with per-leaf fused "
                         "scales and server-side error feedback "
                         "(~4x smaller rows and uplink, seeded-"
                         "deterministic, gated convergence delta vs "
                         "f32)")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="with --quant-bits 8: drop the per-client "
                         "error-feedback residual accumulators "
                         "(ablation — quantization bias goes "
                         "uncorrected)")
    ap.add_argument("--mesh-clients", type=int, default=0,
                    help="shard cohorts over a 1-D client mesh of N "
                         "devices (0 = single-device engine; on CPU "
                         "force devices first with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record runtime telemetry (spans + counters) "
                         "and write the trace here; the aggregate also "
                         "lands in the history's meta['telemetry']")
    ap.add_argument("--trace-format", default="jsonl",
                    choices=["jsonl", "chrome"],
                    help="--trace output format: 'jsonl' = line-delimited "
                         "event log (repro.obs.validate checks it); "
                         "'chrome' = trace_event JSON for "
                         "chrome://tracing / Perfetto")
    ap.add_argument("--report", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="print the per-tier FL run report after the run "
                         "(implies tracing even without --trace); with a "
                         "PATH also write the structured report JSON "
                         "there (see python -m repro.obs.report)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    fl = FLConfig(n_clients=args.clients, n_tiers=args.tiers, tau=args.tau,
                  rounds=args.rounds, mu=args.mu,
                  primary_frac=args.primary_frac, seed=args.seed,
                  lr=1e-3)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    trainer = build_fl_clients(args.arch, fl)
    kw = dict(verbose=True, engine=args.engine,
              use_kernel_agg=args.kernel_agg)
    if args.mesh_clients > 0:
        from repro.distributed import make_client_mesh
        kw["mesh"] = make_client_mesh(args.mesh_clients)
        print(f"[fl_train] client mesh: {kw['mesh'].size} device(s)")
    if args.method in ("fedasync", "fedbuff"):
        kw["window"] = args.window
        kw["window_secs"] = args.window_secs
    if args.no_store and args.method in ("fedasync", "fedbuff",
                                         "feddct_async"):
        kw["use_store"] = False
    if args.hot_rows > 0 and args.method in ("fedasync", "fedbuff",
                                             "feddct_async"):
        kw["store_capacity"] = args.hot_rows
        kw["store_cold_dir"] = args.cold_dir
    if args.quant_bits != 32 and args.method in ("fedasync", "fedbuff",
                                                 "feddct_async"):
        kw["quant_bits"] = args.quant_bits
        kw["error_feedback"] = not args.no_error_feedback
    if args.trace or args.report is not None:
        from repro import obs
        with obs.tracing() as tel:
            hist = run_method(args.method, trainer, net, fl, **kw)
        if args.trace:
            if args.trace_format == "chrome":
                tel.export_chrome(args.trace)
            else:
                tel.export_jsonl(args.trace)
            print(f"[fl_train] trace ({args.trace_format}) -> {args.trace}")
        if args.report is not None:
            import json as _json

            from repro.obs import report as obs_report
            rep = obs_report.build_report(hist.meta["telemetry"],
                                          hist.to_json())
            print(obs_report.format_report(rep, source=args.method))
            if args.report != "-":
                with open(args.report, "w") as f:
                    _json.dump(rep, f, indent=2, sort_keys=True)
                print(f"[fl_train] report json -> {args.report}")
    else:
        hist = run_method(args.method, trainer, net, fl, **kw)
    if hist.accuracy:
        print(f"[fl_train] {args.method} on {args.arch}: "
              f"final acc={hist.accuracy[-1]:.4f} "
              f"virtual time={hist.times[-1]:.1f}s")
    else:
        print(f"[fl_train] {args.method} on {args.arch}: finished before "
              f"the first evaluation (fewer updates than eval_every)")
    if args.out:
        hist.save(args.out)
        print(f"[fl_train] history -> {args.out}")
    return hist


if __name__ == "__main__":
    main()
