"""jit-able train / prefill / serve steps + abstract input specs.

Everything here works on ShapeDtypeStructs (dry-run, zero allocation) and
on real arrays (smoke tests / actual training).  ``input_specs`` returns
the exact stand-ins for every assigned input shape; decode shapes include
the per-layer KV/SSM cache state.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.base import InputShape, ModelConfig, TrainConfig
from repro.models.transformer import (decode_step, forward,
                                      init_decode_state, init_model, lm_loss)
from repro.optim import clip_by_global_norm, make_optimizer
from repro.optim.optimizer import apply_updates

# window used when a full-attention dense arch runs long_500k as its
# sliding-window variant (DESIGN.md §6)
SWA_OVERRIDE_WINDOW = 8192


def _dtype(tcfg: TrainConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[tcfg.dtype]


def swa_window_for(cfg: ModelConfig, shape: InputShape,
                   enabled: bool = True) -> int:
    """-1 = arch default; explicit SWA window for long_500k on every arch
    whose native attention is quadratic / unbounded-cache (dense, vlm,
    and full-attention MoE like arctic).  ``enabled=False`` reproduces the
    pre-hillclimb baseline (dense/vlm only)."""
    if shape.name != "long_500k" or cfg.subquadratic or cfg.family == "ssm":
        return -1
    if enabled or cfg.family in ("dense", "vlm"):
        return SWA_OVERRIDE_WINDOW
    return -1


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape,
                tcfg: TrainConfig = TrainConfig()) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step's data inputs."""
    b, s = shape.global_batch, shape.seq_len
    dt = _dtype(tcfg)
    if shape.kind == "decode":
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.arch_id}: encoder-only, no decode step")
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.family == "audio":
        spec = {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return spec
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def abstract_params(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    dt = _dtype(tcfg)
    return jax.eval_shape(
        lambda: init_model(cfg, jax.random.PRNGKey(0), dtype=dt))


def abstract_opt_state(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    opt = make_optimizer(tcfg.optimizer, weight_decay=tcfg.weight_decay)
    params = abstract_params(cfg, tcfg)
    return jax.eval_shape(opt.init, params)


def abstract_decode_state(cfg: ModelConfig, shape: InputShape,
                          tcfg: TrainConfig = TrainConfig()):
    w = swa_window_for(cfg, shape, enabled=tcfg.long_ctx_swa)
    return jax.eval_shape(
        functools.partial(init_decode_state, cfg, shape.global_batch,
                          shape.seq_len, dtype=_dtype(tcfg), window=w))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig(),
                    lr: Optional[float] = None):
    opt = make_optimizer(tcfg.optimizer, weight_decay=tcfg.weight_decay)
    lr = tcfg.lr if lr is None else lr
    moe_group = tcfg.moe_group_tokens

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, aux = lm_loss(cfg, p, batch, chunk_q=tcfg.attn_chunk_q,
                                chunk_kv=tcfg.attn_chunk_kv,
                                moe_group=moe_group, remat=tcfg.remat,
                                context_parallel=tcfg.context_parallel,
                                seq_parallel=tcfg.seq_parallel,
                                remat_policy=tcfg.remat_policy)
            return loss, aux
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if tcfg.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        else:
            gnorm = jnp.zeros(())
        ups, opt_state = opt.update(grads, opt_state, params, lr)
        params = apply_updates(params, ups)
        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step, opt


def make_prefill_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """Forward over the full prompt; returns last-position logits only
    (the (B,S,V) tensor is never formed — hidden is chunk-projected)."""

    def prefill_step(params, batch):
        hidden, _ = forward(cfg, params, batch, chunk_q=tcfg.attn_chunk_q,
                            chunk_kv=tcfg.attn_chunk_kv,
                            moe_group=tcfg.moe_group_tokens,
                            return_hidden=True,
                            context_parallel=tcfg.context_parallel,
                            seq_parallel=tcfg.seq_parallel)
        last = hidden[:, -1]
        head = params.get("head", None)
        if head is None:
            head = params["embed"].T
        return last @ head

    return prefill_step


def make_serve_step(cfg: ModelConfig, shape: InputShape,
                    tcfg: TrainConfig = TrainConfig()):
    """One decode step: next-token logits + updated cache state."""
    w = swa_window_for(cfg, shape, enabled=tcfg.long_ctx_swa)

    def serve_step(params, state, batch):
        logits, state = decode_step(cfg, params, state, batch["tokens"],
                                    window=w)
        return logits, state

    return serve_step


def make_serve_loop(cfg: ModelConfig, shape: InputShape,
                    tcfg: TrainConfig = TrainConfig(), n_steps: int = 16):
    """N greedy decode steps under one jit (lax.scan).

    This is the honest accounting unit for weight-stationary serving:
    per-token costs that a single-step dry-run charges every token (FSDP
    weight gathers) amortize only if XLA hoists them out of the scan —
    lowering this tells us whether it does (§Perf arctic v4)."""
    w = swa_window_for(cfg, shape, enabled=tcfg.long_ctx_swa)

    def serve_loop(params, state, batch):
        def body(carry, _):
            st, tok = carry
            logits, st = decode_step(cfg, params, st, tok, window=w)
            nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            return (st, nxt), logits[:, -1]
        (state, _), all_logits = jax.lax.scan(
            body, (state, batch["tokens"]), None, length=n_steps)
        return all_logits, state

    return serve_loop


# ---------------------------------------------------------------------------
# Analytic model FLOPs (roofline "useful compute" reference)
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """6*N*D for training (3x fwd matmul flops), 2*N_active*D for
    inference; attention O(S^2) term added for quadratic-attention archs."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.tokens
        base = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.tokens
        base = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        base = 2.0 * n_active * tokens
    # attention score/value flops
    if cfg.family not in ("ssm",) and cfg.n_heads:
        s = shape.seq_len
        w = cfg.sliding_window or (SWA_OVERRIDE_WINDOW
                                   if shape.name == "long_500k" else 0)
        ctx = min(s, w) if w else s
        if shape.kind == "decode":
            att = 4.0 * shape.global_batch * ctx * cfg.q_dim
        else:
            per_tok = ctx if w else s / 2  # causal half
            att = 4.0 * shape.tokens * per_tok * cfg.q_dim
            if shape.kind == "train":
                att *= 3.0
        base += att * cfg.num_layers
    return base
