"""Production mesh factories.

Functions, not module-level constants: importing this module never
touches jax device state.  The single-pod production mesh is 16x16 = 256
chips ("data", "model"); multi-pod is 2x16x16 = 512 chips with a leading
"pod" axis (pure data parallelism across pods — gradient all-reduce is
the only cross-pod collective).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // max(data, 1)), 1)
    return jax.make_mesh((data, model), ("data", "model"))


def make_client_mesh(clients=None, *, devices=None):
    """1-D FL client-axis mesh (the distributed engine's mesh); defined
    in ``repro.distributed.mesh``, re-exported here so launch code has
    a single mesh-factory module.  Pass ``devices=m.devices.flatten()``
    to carve the client axis out of another factory's mesh."""
    from repro.distributed.mesh import make_client_mesh as _make
    return _make(clients, devices=devices)
