"""Centralized (non-FL) training driver for any registered arch.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 100 --batch 8 --seq 128

Runs real optimization on CPU with the reduced config by default; with
--mesh data,model it runs pjit-sharded on however many devices exist.
This is the substrate the FL layer drives; it is also example (b)'s
"train a ~100M model for a few hundred steps" entry point.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import get_arch
from repro.config.base import TrainConfig
from repro.data.synthetic import make_token_dataset
from repro.launch.steps import make_train_step
from repro.sharding import named_shardings, param_specs
from repro.sharding.hints import set_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '1,1' => (data,model) over local devices")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "cnn":
        raise SystemExit("use examples/feddct_mnist.py for CNN workloads")
    tcfg = TrainConfig(dtype="float32", lr=args.lr, remat=False,
                       attn_chunk_q=min(128, args.seq),
                       attn_chunk_kv=min(128, args.seq))

    from repro.models import init_model
    params = init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    step_fn, opt = make_train_step(cfg, tcfg)
    opt_state = opt.init(params)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        set_mesh(mesh)
        p_sh = named_shardings(param_specs(params, mesh), mesh)
        step = jax.jit(step_fn, in_shardings=(p_sh, None, None),
                       out_shardings=(p_sh, None, None))
        ctx = mesh
    else:
        step = jax.jit(step_fn)
        ctx = None

    toks = make_token_dataset(cfg.vocab_size, 400_000, seed=0)
    rng = np.random.default_rng(0)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {cfg.arch_id}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    losses = []
    t0 = time.time()
    for i in range(args.steps):
        starts = rng.integers(0, len(toks) - args.seq - 1, args.batch)
        batch = {"tokens": jnp.asarray(
            np.stack([toks[s:s + args.seq] for s in starts]))}
        if ctx is not None:
            with ctx:
                params, opt_state, metrics = step(params, opt_state, batch)
        else:
            params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0:
            dt = (time.time() - t0) / (i + 1)
            print(f"[train] step {i+1:5d} loss={losses[-1]:.4f} "
                  f"({dt*1e3:.0f} ms/step)")
    set_mesh(None)
    if args.ckpt:
        save_checkpoint(args.ckpt, args.steps,
                        {"params": params, "opt": opt_state})
        print(f"[train] checkpoint saved to {args.ckpt}")
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
