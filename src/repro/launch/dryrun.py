import os

from repro.distributed.hostdevices import ensure_host_device_count

# 512 forced host devices for the multi-pod production meshes.  This
# APPENDS to any XLA_FLAGS the caller already exported (and an existing
# --xla_force_host_platform_device_count wins) instead of clobbering
# the variable — the forced-device-count CI job and local debugging
# flags survive importing this module.  It must still run before jax
# initializes its backend: the device count locks on first backend init.
ensure_host_device_count(512)

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out benchmarks/results/dryrun
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

from repro.config import get_arch
from repro.config.base import INPUT_SHAPES, InputShape, ModelConfig, TrainConfig
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.roofline import analyze_hlo, roofline_terms
from repro.sharding import (batch_specs, decode_state_specs, named_shardings,
                            param_specs)
from repro.sharding.hints import set_mesh

ASSIGNED = [
    "granite-20b", "nemotron-4-340b", "phi4-mini-3.8b", "llama3.2-1b",
    "mixtral-8x7b", "hubert-xlarge", "hymba-1.5b", "arctic-480b",
    "xlstm-350m", "chameleon-34b",
]

# The BASELINE sharding config for the roofline table: megatron TP + FSDP
# without any of the §Perf hillclimb optimizations (those are recorded
# separately by benchmarks/perf_iterate.py).
BASELINE_TCFG = TrainConfig(context_parallel="never", seq_parallel=False,
                            long_ctx_swa=False, decode_headdim_shard=False)


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if cfg.is_encoder_only and shape.kind == "decode":
        return "encoder-only: no decode step (DESIGN.md §6)"
    return None


def variant_note(cfg: ModelConfig, shape: InputShape,
                 tcfg: TrainConfig) -> str:
    if steps_lib.swa_window_for(cfg, shape, enabled=tcfg.long_ctx_swa) > 0:
        return f"swa-{steps_lib.SWA_OVERRIDE_WINDOW}"
    return "native"


def run_one(arch: str, shape_name: str, multi_pod: bool,
            tcfg: TrainConfig = None, verbose: bool = True
            ) -> Dict:
    if tcfg is None:
        tcfg = BASELINE_TCFG
    from repro.models import attention as _attn
    _attn.DECODE_HEADDIM_SHARD = tcfg.decode_headdim_shard
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "variant": variant_note(cfg, shape, tcfg)}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh, fsdp_only=tcfg.parallelism == "fsdp_only")
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()

    params = steps_lib.abstract_params(cfg, tcfg)
    p_specs = param_specs(params, mesh, fsdp=tcfg.fsdp,
                          mode=tcfg.parallelism)
    p_shard = named_shardings(p_specs, mesh)
    batch = steps_lib.input_specs(cfg, shape, tcfg)
    b_shard = named_shardings(batch_specs(batch, mesh,
                                          mode=tcfg.parallelism), mesh)

    if shape.kind == "train":
        opt_state = steps_lib.abstract_opt_state(cfg, tcfg)
        o_specs = _opt_specs(opt_state, params, mesh, tcfg)
        o_shard = named_shardings(o_specs, mesh)
        step, _ = steps_lib.make_train_step(cfg, tcfg)
        fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None))
        with mesh:
            lowered = fn.lower(params, opt_state, batch)
    elif shape.kind == "prefill":
        step = steps_lib.make_prefill_step(cfg, tcfg)
        fn = jax.jit(step, in_shardings=(p_shard, b_shard),
                     out_shardings=None)
        with mesh:
            lowered = fn.lower(params, batch)
    else:  # decode
        state = steps_lib.abstract_decode_state(cfg, shape, tcfg)
        s_shard = named_shardings(decode_state_specs(state, mesh), mesh)
        step = steps_lib.make_serve_step(cfg, shape, tcfg)
        fn = jax.jit(step, in_shardings=(p_shard, s_shard, b_shard),
                     out_shardings=(None, s_shard))
        with mesh:
            lowered = fn.lower(params, state, batch)

    with mesh:
        compiled = lowered.compile()
    set_mesh(None)
    rec["compile_s"] = round(time.time() - t0, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes_per_device": int(mem.argument_size_in_bytes),
        "output_bytes_per_device": int(mem.output_size_in_bytes),
        "temp_bytes_per_device": int(mem.temp_size_in_bytes),
        "alias_bytes_per_device": int(mem.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # older jax: one dict per device
        ca = ca[0] if ca else {}
    rec["xla_cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}

    hlo = analyze_hlo(compiled.as_text())
    rec["hlo"] = {k: (v if not isinstance(v, dict) else v)
                  for k, v in hlo.items()}

    # --- roofline (per-chip quantities parsed from SPMD HLO) -----------
    # memory term: bytes-accessed from cost_analysis undercounts scanned
    # bodies exactly like flops do; scale it by the same ratio.
    flops_pc = hlo["dot_flops"]
    xla_flops = max(rec["xla_cost"]["flops"], 1.0)
    scan_ratio = max(flops_pc / xla_flops, 1.0)
    bytes_pc = rec["xla_cost"]["bytes_accessed"] * scan_ratio
    terms = roofline_terms(hlo_flops=flops_pc, hbm_bytes=bytes_pc,
                           collective_bytes=hlo["collective_wire_bytes"],
                           chips=1)
    mf = steps_lib.model_flops(cfg, shape)
    terms["model_flops_global"] = mf
    terms["hlo_flops_global"] = flops_pc * n_chips
    terms["useful_ratio"] = mf / max(flops_pc * n_chips, 1.0)
    rec["roofline"] = terms
    rec["status"] = "ok"
    if verbose:
        print(f"[dryrun] {arch:16s} {shape_name:12s} {mesh_name:8s} "
              f"{rec['variant']:10s} compile={rec['compile_s']:6.1f}s "
              f"dom={terms['dominant']:12s} bound={terms['bound_s']:.4f}s "
              f"useful={terms['useful_ratio']:.2f}", flush=True)
    return rec


def _opt_specs(opt_state, params, mesh, tcfg):
    """Optimizer moments shard like their parameter; scalars replicate."""
    from jax.sharding import PartitionSpec as P
    p_specs = param_specs(params, mesh, fsdp=tcfg.fsdp,
                          mode=tcfg.parallelism)

    def match(o_leaf_path, o_leaf):
        return None

    # adam state: {"m": tree, "v": tree, "t": scalar}
    if isinstance(opt_state, dict) and "m" in opt_state:
        return {"m": p_specs, "v": p_specs, "t": P()}
    if isinstance(opt_state, tuple) and len(opt_state) == 0:
        return ()
    return jax.tree_util.tree_map(lambda _: P(), opt_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached")
                    results.append(json.load(open(path)))
                    continue
                try:
                    rec = run_one(arch, shape, mp)
                except Exception as e:  # fedlint: disable=FED007 -- sweep harness records the per-arch failure and continues
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[dryrun] {tag}: ERROR {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                results.append(rec)
    ok = sum(1 for r in results if r.get("status") == "ok")
    sk = sum(1 for r in results if r.get("status") == "skipped")
    er = sum(1 for r in results if r.get("status") == "error")
    print(f"[dryrun] done: {ok} ok, {sk} skipped, {er} errors")
    return 0 if er == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
