"""Aggregation buffer: windowed draining of client completions.

The knob that spans the async design space:

* ``window=0, window_secs=0`` — every drain returns exactly ONE event:
  the degenerate case is today's one-at-a-time FedAsync merge.
* ``window=K`` — FedBuff-style count window: the drain collects the K
  earliest completions (the server waits for a goal number of updates
  before aggregating).
* ``window_secs=T`` — time window: the drain anchors on the earliest
  pending completion and collects everything finishing within T
  virtual seconds of it (Zhou et al.'s time-triggered batching).
* both — count cap AND time deadline, whichever closes first.

``drain_until`` is the externally-anchored variant used by the
semi-async FedDCT loop, where a per-tier timeout (Eq. 7) — not the
anchor event — sets the deadline.
"""

from __future__ import annotations

import math
from typing import List, Optional

from repro.obs import telemetry as obs
from repro.runtime.events import ClientEvent, EventQueue


class AggregationBuffer:
    def __init__(self, window: int = 0, window_secs: float = 0.0):
        if window < 0 or window_secs < 0:
            raise ValueError("window and window_secs must be >= 0")
        self.window = int(window)
        self.window_secs = float(window_secs)

    def _cap(self, limit: Optional[int]) -> float:
        if self.window > 0:
            cap = self.window
        elif self.window_secs > 0:
            cap = math.inf
        else:
            cap = 1                       # sequential FedAsync
        return cap if limit is None else min(cap, limit)

    def drain(self, queue: EventQueue,
              limit: Optional[int] = None) -> List[ClientEvent]:
        """Pop one window of completions (>= 1 event; the anchor is the
        earliest pending completion).  ``limit`` hard-caps the count
        (the runner's remaining update budget)."""
        tel = obs.TEL
        tel.gauge("queue.depth", len(queue))
        if not queue:
            tel.inc("drain.queue_empty")
            return []
        anchor = queue.pop()
        batch = [anchor]
        cap = self._cap(limit)
        deadline = (anchor.finish + self.window_secs
                    if self.window_secs > 0 else math.inf)
        while queue and len(batch) < cap and queue.peek().finish <= deadline:
            batch.append(queue.pop())
        # classify what closed the window (counter catalogue: drain.*)
        if len(batch) >= cap:
            if limit is not None and cap == limit and (
                    self.window == 0 or limit < self.window):
                tel.inc("drain.budget")
            elif self.window > 0:
                tel.inc("drain.count")
            else:
                tel.inc("drain.sequential")
        elif self.window_secs > 0:
            tel.inc("drain.deadline")
        else:
            tel.inc("drain.queue_drained")
        return batch

    def peek_window(self, queue: EventQueue,
                    limit: Optional[int] = None) -> List[ClientEvent]:
        """The events the NEXT ``drain`` would return, without popping
        — the residency prefetcher's lookahead.  Mirrors ``drain``'s
        anchor/cap/deadline logic over ``peek_n``'s sorted prefix, so
        the result matches the coming drain exactly (events pushed in
        between can only make the real drain a sub-case: gather/merge
        re-stage anything the prefetch missed)."""
        if not queue:
            return []
        cap = self._cap(limit)
        k = len(queue) if math.isinf(cap) else min(int(cap), len(queue))
        events = queue.peek_n(k)
        if self.window_secs > 0:
            deadline = events[0].finish + self.window_secs
            events = [e for e in events if e.finish <= deadline]
        return events

    def close_time(self, batch: List[ClientEvent],
                   limit: Optional[int] = None) -> float:
        """Virtual time at which the server actually closes a drained
        window.

        A count-closed window (the K-th / budget-capped completion
        arrived) closes at the last arrival.  A time-closed window
        closes at ``anchor + window_secs``: a real time-triggered
        server cannot know no further completion is coming, so it must
        wait out the full deadline even if the last arrival was
        earlier.
        """
        if self.window_secs > 0 and len(batch) < self._cap(limit):
            return batch[0].finish + self.window_secs
        return batch[-1].finish

    @staticmethod
    def drain_until(queue: EventQueue, deadline: float,
                    limit: Optional[int] = None) -> List[ClientEvent]:
        """Pop every completion with ``finish <= deadline`` (possibly
        none) — the semi-async FedDCT window, where the tier timeout
        sets the deadline before any event is seen."""
        tel = obs.TEL
        tel.gauge("queue.depth", len(queue))
        batch: List[ClientEvent] = []
        cap = math.inf if limit is None else limit
        while queue and len(batch) < cap and queue.peek().finish <= deadline:
            batch.append(queue.pop())
        if len(batch) >= cap:
            tel.inc("drain.budget")
        else:
            tel.inc("drain.deadline")
        return batch

    @staticmethod
    def peek_until(queue: EventQueue, deadline: float,
                   limit: Optional[int] = None) -> List[ClientEvent]:
        """The events the next ``drain_until(deadline)`` would return,
        without popping — lookahead for the semi-async FedDCT loop
        (the tier timeout is known BEFORE the window opens, so the
        whole coming window can prefetch)."""
        if not queue:
            return []
        k = len(queue) if limit is None else min(int(limit), len(queue))
        return [e for e in queue.peek_n(k) if e.finish <= deadline]
