"""Deterministic event queue for the virtual-clock async runtime.

Every FL method in this repo is compared on the *identical*
``WirelessNetwork`` realization, so the event order must be a pure
function of the sampled delays: events are a min-heap over
``(finish_time, client)`` — finish-time ties break on the lower client
id, never on heap insertion order.  The payload fields (model version
at start, per-client round index, sampled cost) do not participate in
ordering.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True, order=True)
class ClientEvent:
    """One client finishing its local training at virtual ``finish``.

    ``version`` is the global model version the client STARTED from
    (its staleness at merge time is ``current_version - version``);
    ``rnd`` is the client's own round counter (seeds its data stream);
    ``cost`` is the sampled wall-clock of this attempt (== the delay
    draw that produced ``finish``), kept for schedulers that maintain
    running-average client times.
    """

    finish: float
    client: int
    version: int = field(default=0, compare=False)
    rnd: int = field(default=0, compare=False)
    cost: float = field(default=0.0, compare=False)


class EventQueue:
    """Min-heap of ``ClientEvent`` with deterministic tie-breaking."""

    def __init__(self, events: Optional[List[ClientEvent]] = None):
        self._heap: List[ClientEvent] = list(events or [])
        heapq.heapify(self._heap)

    def push(self, event: ClientEvent) -> None:
        heapq.heappush(self._heap, event)

    def pop(self) -> ClientEvent:
        return heapq.heappop(self._heap)

    def peek(self) -> ClientEvent:
        return self._heap[0]

    def peek_n(self, k: int) -> List[ClientEvent]:
        """The ``k`` earliest pending events in pop order, WITHOUT
        popping — the residency prefetcher's lookahead.  ``heapq.
        nsmallest`` sorts on the same ``(finish, client)`` total order
        as ``pop``, so the returned prefix matches the next ``k`` pops
        exactly and the heap is untouched."""
        if k <= 0:
            return []
        return heapq.nsmallest(k, self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
