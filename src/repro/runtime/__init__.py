"""Event-driven async federation runtime (virtual clock).

Three pieces, composable from the bottom up:

* ``events``  — ``EventQueue``: a deterministic min-heap of client
  completions ordered by ``(finish_time, client)`` so every method
  replays the identical ``WirelessNetwork`` realization.
* ``buffer``  — ``AggregationBuffer``: drains completions in windows
  (``window=0`` = sequential FedAsync, ``window=K`` = FedBuff count
  goal, ``window_secs=T`` = time-triggered batching).
* ``async_loop`` — ``AsyncRunner`` (each drained window trains as one
  vmapped cohort, merged with per-row staleness weights fused into the
  stacked aggregation path) and ``run_feddct_async`` (FedDCT's
  per-tier timeouts reinterpreted as window deadlines).
"""

from repro.runtime.async_loop import AsyncRunner, run_feddct_async
from repro.runtime.buffer import AggregationBuffer
from repro.runtime.events import ClientEvent, EventQueue

__all__ = ["AggregationBuffer", "ClientEvent", "EventQueue",
           "AsyncRunner", "run_feddct_async"]
