"""Event-driven async federation over a virtual clock.

``AsyncRunner`` generalizes the repo's FedAsync loop: client
completions stream through a deterministic ``EventQueue``, an
``AggregationBuffer`` drains them in windows, and each drained window
trains as ONE vmapped cohort through the batched execution engine
(every client from its OWN model snapshot, with its own data-stream
seed) before a single fused staleness-weighted merge
(``alpha_i = alpha * (s_i + 1)^-a`` per row).

Client snapshots live in a device-resident ``ClientStateStore`` — one
flat (N, P) buffer, gathered per window and re-scattered by the fused
(donating) merge+scatter program — instead of a ``Dict[int, pytree]``
of N scattered copies; ``use_store=False`` keeps the dict path as the
bit-identical A/B reference.

* ``window=0``            -> one event per drain: history-identical to
  the legacy sequential FedAsync implementation (singleton windows take
  the exact legacy code path: ``train_clients`` + ``staleness_merge``).
* ``window=K``            -> FedBuff [Nguyen'22]-style semi-async: wait
  for K completions, merge them as one cohort.
* ``window_secs=T``       -> time-triggered batching [Zhou'22]: merge
  everything that lands within T virtual seconds of the anchor event.

``run_feddct_async`` is the semi-async FedDCT variant: CSTT still
selects tau clients from tiers 1..t every round, but the per-tier
timeout D_max^t (Eq. 7) becomes the round's aggregation-window
*deadline* instead of a drop threshold — a selected client that misses
the window is NOT discarded; its completion stays queued and merges in
a later round, discounted by its staleness.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from repro.config.base import FLConfig
from repro.core.aggregation import staleness_merge
from repro.core.engine import make_engine
from repro.core.selection import cstt
from repro.core.state import ClientStateStore, wire_bytes
from repro.core.tiering import evaluate_client, tiering, update_avg_time
from repro.fl.metrics import RunHistory
from repro.obs import flstats
from repro.obs import telemetry as obs
from repro.runtime.buffer import AggregationBuffer
from repro.runtime.events import ClientEvent, EventQueue


def _resolve_store(params, n_clients: int, mesh, use_store,
                   window_active: bool, capacity=None, cold_dir=None,
                   quant_bits: int = 32, error_feedback: bool = True):
    """-> ``(ClientStateStore or None, reason)`` applying the store
    policy in one place.  ``None`` store means the dict-of-pytrees
    path; ``reason`` is a machine-checkable tag recorded on the
    ``RunHistory`` (``meta["store_reason"]``) so benchmarks and tests
    can assert which path actually ran instead of sniffing warnings:

    * ``use_store=None`` (default) enables the store exactly when
      windows can batch — a pure ``window=0`` sequential loop has no
      stacking to amortize, so the dict path's free reference rebind
      wins there (reason ``"window0-sequential"``);
    * ``use_store=False`` keeps the dict reference path (reason
      ``"forced-off"``);
    * otherwise the store is constructed, full stop — the fused window
      step dispatches the Pallas fedagg kernel when asked and the
      store carries non-float leaves in its int32 sidecar segment, so
      there is no configuration left to degrade on.  A template the
      store genuinely cannot hold exactly (64-bit leaves) raises
      ``TypeError`` loudly instead of silently changing paths.

    ``capacity`` (client rows the device keeps hot) selects tiered
    residency: the store becomes a ``TieredClientStateStore`` whose
    cold tier is pinned host memory, or ckpt-chunk disk spill when
    ``cold_dir`` is set.  Asking for a capacity implies wanting the
    store (reason ``"auto-tiered"``) — except under an explicit
    ``use_store=False``, which still wins.  Histories are bit-identical
    across all residency layouts, so this only moves memory.

    ``quant_bits=8`` selects int8 quantized rows (+ ``error_feedback``
    residual accumulators).  The quantized format IS the store — there
    is no dict-of-pytrees rendition of it — so it forces the store on
    even for a pure sequential ``window=0`` loop (reason
    ``"quant-int8"``) and an explicit ``use_store=False`` raises
    instead of silently running unquantized.
    """
    quant = int(quant_bits) != 32
    if use_store is False:
        if quant:
            raise ValueError(
                "quant_bits=8 lives in the client-state store; it cannot "
                "combine with use_store=False (the dict path has no "
                "quantized rows)")
        return None, "forced-off"
    qkw = dict(quant_bits=quant_bits, error_feedback=error_feedback)
    if capacity is not None:
        from repro.core.residency import TieredClientStateStore
        reason = "forced-on" if use_store is True else "auto-tiered"
        return TieredClientStateStore(
            params, n_clients, capacity=capacity,
            cold="disk" if cold_dir else "host", cold_dir=cold_dir,
            mesh=mesh, **qkw), reason
    if use_store is None and not window_active:
        if quant:
            return (ClientStateStore(params, n_clients, mesh=mesh, **qkw),
                    "quant-int8")
        return None, "window0-sequential"
    reason = "forced-on" if use_store is True else "auto-windowed"
    return ClientStateStore(params, n_clients, mesh=mesh, **qkw), reason


def _alphas(fl: FLConfig, stalenesses: List[int]) -> List[float]:
    """Per-row merge weights alpha_i = alpha * (s_i + 1)^-a (or the
    constant-alpha variant), matching the legacy scalar formula."""
    if fl.async_staleness == "poly":
        return [fl.async_alpha * (s + 1.0) ** (-fl.async_a)
                for s in stalenesses]
    return [fl.async_alpha] * len(stalenesses)


def _event_seed(e: ClientEvent) -> int:
    """Data-stream seed of one completion — the legacy formula, shared
    by the dict and store merge paths so the bit-identity gate cannot
    drift on a one-sided edit."""
    return e.rnd * 977 + e.client


def _window_alphas(fl: FLConfig, batch: List[ClientEvent],
                   version: int) -> List[float]:
    """Per-row merge weights of a drained window: staleness of row i is
    ``(version + i) - event.version`` — exactly the bookkeeping a
    one-at-a-time merge loop would produce."""
    return _alphas(fl, [version + i - e.version
                        for i, e in enumerate(batch)])


def _merge_window(eng, params, snapshots: Dict[int, object],
                  batch: List[ClientEvent], fl: FLConfig, version: int):
    """Train one drained window and merge it into ``params`` (the
    dict-of-pytrees reference path, kept for A/B tests and benchmarks
    against the store-backed hot path).

    Row order = heap-pop order = sequential merge order; staleness of
    row i is ``(version + i) - event.version`` — exactly the bookkeeping
    a one-at-a-time merge loop would produce.  A singleton window takes
    the legacy path (same jitted program, same float ops) so
    ``window=0`` reproduces sequential FedAsync bit-for-bit.
    """
    if len(batch) == 1:
        e = batch[0]
        stacked, _ = eng.train_clients(snapshots[e.client], [e.client],
                                       _event_seed(e))
        new_p = jax.tree_util.tree_map(lambda l: l[0], stacked)
        return staleness_merge(params, new_p,
                               _window_alphas(fl, batch, version)[0])
    starts = [snapshots[e.client] for e in batch]
    ids = [e.client for e in batch]
    seeds = [_event_seed(e) for e in batch]
    stacked, _ = eng.train_cohort(starts, ids, seeds)
    return eng.merge_staleness(params, stacked,
                               _window_alphas(fl, batch, version))


def _merge_window_store(eng, store: ClientStateStore, params,
                        batch: List[ClientEvent], fl: FLConfig,
                        version: int):
    """Store-backed ``_merge_window``: snapshots are gathered from the
    device-resident (N, P) buffer and the merged window scatters the
    new global row back in ONE donated program
    (``engine.train_window``).  Histories are bit-identical to the
    dict path (gather/scatter round-trips are exact; the merge is the
    same folded program; padded rows contribute exact zero terms) on
    backends whose row reduction is sequential — XLA CPU, where the
    gates run.  A backend that tree-reduces rows may regroup the
    nonzero terms across the pad boundary, degrading the equality to
    float tolerance.  A singleton window still takes the legacy train
    + ``staleness_merge`` path, preserving the ``window=0``
    sequential-FedAsync gate."""
    if len(batch) == 1:
        e = batch[0]
        stacked, _ = eng.train_clients(store.gather_one(e.client),
                                       [e.client], _event_seed(e))
        new_p = jax.tree_util.tree_map(lambda l: l[0], stacked)
        params = staleness_merge(params, new_p,
                                 _window_alphas(fl, batch, version)[0])
        store.scatter_params([e.client], params)
        return params
    ids = [e.client for e in batch]
    seeds = [_event_seed(e) for e in batch]
    params, _ = eng.train_window(store, params, ids, seeds,
                                 _window_alphas(fl, batch, version))
    return params


class AsyncRunner:
    """Virtual-clock event loop: drain window -> vmapped cohort ->
    fused staleness merge -> reschedule the merged clients."""

    def __init__(self, trainer, network, fl: FLConfig, *,
                 method: str = "fedasync", engine: str = "batched",
                 use_kernel_agg: bool = False, window: int = 0,
                 window_secs: float = 0.0, eval_every: int = 5,
                 verbose: bool = False, mesh=None, use_store=None,
                 store_capacity=None, store_cold_dir=None,
                 quant_bits: int = 32, error_feedback: bool = True):
        self.trainer = trainer
        self.network = network
        self.fl = fl
        self.method = method
        self.engine = engine
        self.use_kernel_agg = use_kernel_agg
        # client mesh for the distributed engine: windowed cohorts train
        # under shard_map and merge via the sharded psum reduction
        # (singleton windows keep the legacy single-device merge path,
        # preserving the window=0 history gate).
        self.mesh = mesh
        # device-resident client-state store: all N snapshots live as
        # one flat (N, P) buffer.  Tri-state: None (default) = on for
        # windowed modes, off for the pure sequential window=0 loop;
        # False = dict-of-pytrees A/B reference (bit-identical
        # histories, slower server step); True = force (window=0
        # included).  Resolved by ``_resolve_store`` at run().
        self.use_store = use_store
        # tiered residency: hot device rows (None = dense, every row on
        # device) and the optional disk cold tier for the demoted rest.
        self.store_capacity = store_capacity
        self.store_cold_dir = store_cold_dir
        # row format: 32 = the byte-for-byte f32 path, 8 = int8
        # quantized rows (+ server-side error-feedback accumulators
        # unless error_feedback=False) — seeded-deterministic with a
        # gated convergence delta vs f32, never bit-identical to it.
        self.quant_bits = int(quant_bits)
        self.error_feedback = bool(error_feedback)
        # resolved snapshot-path tag ("auto-windowed" / "forced-on" /
        # "forced-off" / "window0-sequential" / "auto-tiered"), set by
        # run() and also recorded on the RunHistory meta.
        self.store_reason = None
        self.buffer = AggregationBuffer(window, window_secs)
        self.eval_every = max(int(eval_every), 1)
        self.verbose = verbose
        self.cohort_sizes: List[int] = []

    def run(self) -> RunHistory:
        fl, net = self.fl, self.network
        tel = obs.TEL
        run_span = tel.span("run", method=self.method).start()
        eng = make_engine(self.trainer, use_kernel_agg=self.use_kernel_agg,
                          engine=self.engine, mesh=self.mesh)
        params = self.trainer.init_params(fl.seed)
        # true async: each client trains from the global model snapshot
        # taken when it STARTED (not finished) — staleness weights exist
        # to correct exactly that lag.
        store, self.store_reason = _resolve_store(
            params, fl.n_clients, self.mesh, self.use_store,
            window_active=(self.buffer.window > 0
                           or self.buffer.window_secs > 0),
            capacity=self.store_capacity, cold_dir=self.store_cold_dir,
            quant_bits=self.quant_bits, error_feedback=self.error_feedback)
        # modeled uplink bytes of one merged client update in the run's
        # row format (the store's if one runs, else dense f32)
        wb = (store.wire_bytes_per_update if store is not None
              else wire_bytes(params, self.quant_bits))
        snapshots: Dict[int, object] = {}
        if store is None:
            snapshots = {c: params for c in range(fl.n_clients)}
        hist = RunHistory(
            method=self.method, arch=self.trainer.cfg.arch_id,
            meta={"mu": fl.mu, "primary_frac": fl.primary_frac,
                  "alpha": fl.async_alpha, "a": fl.async_a,
                  "engine": self.engine, "window": self.buffer.window,
                  "window_secs": self.buffer.window_secs,
                  "store": store is not None,
                  "store_path": "store" if store is not None else "dict",
                  "store_reason": self.store_reason,
                  "residency": (store.residency if store is not None
                                else "dict"),
                  "hot_rows": store.rows if store is not None else 0,
                  "kernel_agg": self.use_kernel_agg,
                  "quant_bits": (store.quant_bits if store is not None
                                 else 32),
                  "error_feedback": (store.error_feedback
                                     if store is not None else False),
                  "wire_bytes_per_update": wb,
                  "mesh_devices": (int(self.mesh.size)
                                   if self.mesh is not None else 1)})
        first = net.delays(np.arange(fl.n_clients), 0)
        q = EventQueue([ClientEvent(float(t), c, 0, 0, cost=float(t))
                        for c, t in enumerate(first)])
        # budget: same number of merges as the sync methods have
        # rounds * tau client updates
        max_updates = fl.rounds * fl.tau
        version, upd, clock = 0, 0, 0.0
        prev_peek = None   # lookahead accuracy: last prefetch's forecast
        while upd < max_updates and q:
            limit = max_updates - upd
            batch = self.buffer.drain(q, limit=limit)
            # count-closed windows close at the K-th arrival; time-closed
            # windows close at anchor + window_secs (the server must wait
            # out the deadline — it cannot know nothing else is coming)
            clock = self.buffer.close_time(batch, limit=limit)
            tel.set_virtual_time(clock)
            tel.observe("cohort.size", len(batch))
            if prev_peek is not None:
                hits = sum(1 for e in batch if e.client in prev_peek)
                tel.inc("lookahead.hit", hits)
                tel.inc("lookahead.miss", len(batch) - hits)
                prev_peek = None
            if hasattr(store, "prefetch") and q and limit > len(batch):
                # EventQueue lookahead: the finish times of the NEXT
                # window are already in the heap, so its rows stage
                # host->device while the current cohort trains.  The
                # in-flight batch is pinned against eviction; the peek
                # never perturbs pop order, and a stale hint only costs
                # swaps (gather/merge re-stage anything missing).
                with tel.span("window.prefetch"):
                    upcoming = self.buffer.peek_window(
                        q, limit=limit - len(batch))
                    store.prefetch([e.client for e in upcoming],
                                   keep=[e.client for e in batch])
                prev_peek = {e.client for e in upcoming}
            if tel.enabled:
                flstats.record_staleness(
                    [version + i - e.version for i, e in enumerate(batch)])
                flstats.record_client_updates([e.client for e in batch])
                # tier-less runners: one unlabeled uplink count per window
                flstats.record_uplink(len(batch) * wb)
            with tel.span("window.merge", cohort=len(batch)):
                if store is not None:
                    # the merged clients' snapshot rows are re-scattered
                    # inside the fused window step itself
                    params = _merge_window_store(eng, store, params, batch,
                                                 fl, version)
                else:
                    params = _merge_window(eng, params, snapshots, batch,
                                           fl, version)
            version += len(batch)
            self.cohort_sizes.append(len(batch))
            with tel.span("window.reschedule", cohort=len(batch)):
                rnds = np.asarray([e.rnd + 1 for e in batch])
                nxt = net.delays([e.client for e in batch], rnds)
                for e, t in zip(batch, nxt):
                    if store is None:
                        snapshots[e.client] = params
                    q.push(ClientEvent(clock + float(t), e.client, version,
                                       e.rnd + 1, cost=float(t)))
            prev_upd, upd = upd, upd + len(batch)
            if upd // self.eval_every > prev_upd // self.eval_every:
                with tel.span("eval"):
                    acc = self.trainer.evaluate(params)
                hist.record(time=clock, rnd=upd, acc=acc,
                            n_selected=len(batch))
                if self.verbose:
                    print(f"[{self.method}] u={upd:5d} t={clock:9.1f}s "
                          f"acc={acc:.4f} cohort={len(batch)}")
                if fl.target_accuracy and acc >= fl.target_accuracy:
                    break
        # terminal eval: the loop can exit between eval points (budget
        # exhausted off-cadence) — always record the true final state.
        if not hist.rounds or hist.rounds[-1] != upd:
            with tel.span("eval"):
                acc = self.trainer.evaluate(params)
            hist.record(time=clock, rnd=upd, acc=acc,
                        n_selected=self.cohort_sizes[-1]
                        if self.cohort_sizes else 0)
        hist.meta["mean_cohort"] = (float(np.mean(self.cohort_sizes))
                                    if self.cohort_sizes else 0.0)
        hist.meta["n_drains"] = len(self.cohort_sizes)
        # cumulative modeled uplink: every merged update paid one wire
        # row (telemetry-independent — derived from the merge count)
        hist.meta["bytes_up"] = upd * wb
        if store is not None:
            bt = store.bytes_by_tier()
            hist.meta["store_bytes_hot"] = bt["hot"]
            hist.meta["store_bytes_cold"] = bt["cold"]
            hist.meta["store_bytes_ef"] = bt["ef"]
        run_span.end()
        tel.summarize_into(hist.meta)
        return hist


def run_feddct_async(trainer, network, fl: FLConfig, *,
                     engine: str = "batched", use_kernel_agg: bool = False,
                     verbose: bool = False, eval_every: int = 1,
                     mesh=None, use_store=None, store_capacity=None,
                     store_cold_dir=None, quant_bits: int = 32,
                     error_feedback: bool = True) -> RunHistory:
    """Semi-async FedDCT: tier timeouts become aggregation windows.

    Per round: dynamic tiering + CSTT selection exactly as the sync
    scheduler (over clients not currently in flight), but selected
    clients are pushed as completion events and the round drains every
    completion inside ``deadline = max_k min(D_max^k, Omega)`` (Eq. 7
    as a window, Eq. 5/6 as the clock advance).  Clients that miss the
    window stay in flight — merged later with a staleness-discounted
    alpha instead of being dropped, so no local work is ever wasted
    (there is no re-evaluation lane: the merge itself refreshes the
    client's running-average time).
    """
    rng = np.random.default_rng(fl.seed + 19)
    tel = obs.TEL
    run_span = tel.span("run", method="feddct_async").start()
    eng = make_engine(trainer, use_kernel_agg=use_kernel_agg, engine=engine,
                      mesh=mesh)
    params = trainer.init_params(fl.seed)
    # snapshot-at-selection state: store rows (device-resident flat
    # buffer) by default — tier windows always batch — with the
    # dict-of-pytrees path as the A/B reference (use_store=False)
    store, store_reason = _resolve_store(params, fl.n_clients, mesh,
                                         use_store, window_active=True,
                                         capacity=store_capacity,
                                         cold_dir=store_cold_dir,
                                         quant_bits=quant_bits,
                                         error_feedback=error_feedback)
    wb = (store.wire_bytes_per_update if store is not None
          else wire_bytes(params, quant_bits))
    hist = RunHistory(method="feddct_async", arch=trainer.cfg.arch_id,
                      meta={"mu": fl.mu, "primary_frac": fl.primary_frac,
                            "beta": fl.beta, "kappa": fl.kappa,
                            "omega": fl.omega, "tau": fl.tau,
                            "n_tiers": fl.n_tiers, "engine": engine,
                            "alpha": fl.async_alpha, "a": fl.async_a,
                            "store": store is not None,
                            "store_path": ("store" if store is not None
                                           else "dict"),
                            "store_reason": store_reason,
                            "residency": (store.residency
                                          if store is not None else "dict"),
                            "hot_rows": (store.rows if store is not None
                                         else 0),
                            "kernel_agg": use_kernel_agg,
                            "quant_bits": (store.quant_bits
                                           if store is not None else 32),
                            "error_feedback": (store.error_feedback
                                               if store is not None
                                               else False),
                            "wire_bytes_per_update": wb,
                            "mesh_devices": (int(mesh.size)
                                             if mesh is not None else 1)})
    clock = 0.0

    # initial kappa-round evaluation of every client (parallel), exactly
    # like the sync scheduler
    at: Dict[int, float] = {}
    ct: Dict[int, int] = {}
    setup_times = []
    for c in range(fl.n_clients):
        t_avg, spent = evaluate_client(network, c, rnd=0, kappa=fl.kappa,
                                       omega=fl.omega)
        at[c] = t_avg
        ct[c] = 0
        setup_times.append(spent)
    clock += max(setup_times)

    q = EventQueue()
    snapshots: Dict[int, object] = {}
    inflight: Dict[int, int] = {}          # client -> tier at selection
    version = 0
    t_ptr = 1
    v_curr = v_prev = 0.0
    m = max(fl.n_clients // fl.n_tiers, 1)
    cohort_sizes: List[int] = []

    for rnd in range(1, fl.rounds + 1):
        tel.set_virtual_time(clock)
        avail_at = {c: v for c, v in at.items() if c not in inflight}
        deadline = clock + fl.omega
        n_sel = 0
        if avail_at:
            sel_span = tel.span("round.select", avail=len(avail_at)).start()
            tiers = tiering(avail_at, m)
            selected, d_max, t_ptr = cstt(
                t_ptr, v_prev, v_curr, tiers, avail_at, ct, fl.tau,
                fl.beta, fl.omega, rng)
            flstats.record_tiering(
                tiers, thresholds=[min(d, fl.omega) for d in d_max],
                population=fl.n_clients)
            flstats.record_selection(selected)
            sts = network.delays([c for c, _ in selected], rnd)
            used = {k for _, k in selected}
            if used:
                deadline = clock + max(min(d_max[k], fl.omega)
                                       for k in used)
            for (c, k), st in zip(selected, sts):
                q.push(ClientEvent(clock + float(st), c, version, rnd,
                                   cost=float(st)))
                if store is None:
                    snapshots[c] = params
                inflight[c] = k
                # a client whose completion lands past the round's
                # window deadline is this design's "timeout hit" — it
                # is carried, not dropped, but it missed its tier's
                # response budget all the same.
                flstats.record_response(
                    k + 1, float(st), min(d_max[k], fl.omega),
                    timed_out=clock + float(st) > deadline)
            if store is not None and selected:
                # one scatter snapshots the whole selection at once
                store.scatter_params([c for c, _ in selected], params)
            n_sel = len(selected)
            sel_span.end()

        peeked = None
        if hasattr(store, "prefetch") and q:
            # the tier timeout is known BEFORE the window opens: every
            # completion the coming drain will pop can stage
            # host->device now, while selection's device work retires.
            with tel.span("window.prefetch"):
                upcoming = AggregationBuffer.peek_until(q, deadline)
                store.prefetch([e.client for e in upcoming])
            peeked = {e.client for e in upcoming}
        batch = AggregationBuffer.drain_until(q, deadline)
        tel.observe("cohort.size", len(batch))
        if peeked is not None:
            hits = sum(1 for e in batch if e.client in peeked)
            tel.inc("lookahead.hit", hits)
            tel.inc("lookahead.miss", len(batch) - hits)
        if batch:
            # completions selected in an EARLIER round merging now are
            # stragglers the semi-async design carried instead of drops
            carried = sum(1 for e in batch if e.rnd < rnd)
            if carried:
                tel.inc("stragglers.carried", carried)
            if tel.enabled:
                tiers_of = [inflight[e.client] + 1
                            if e.client in inflight else None
                            for e in batch]
                flstats.record_staleness(
                    [version + i - e.version for i, e in enumerate(batch)],
                    tiers_of)
                flstats.record_client_updates([e.client for e in batch])
                for e, t in zip(batch, tiers_of):
                    # per-tier modeled uplink: tier known at selection
                    flstats.record_uplink(wb, tier=t)
                    if e.rnd < rnd:
                        flstats.record_straggler("carried", tier=t)
            with tel.span("window.merge", cohort=len(batch)):
                if store is not None:
                    params = _merge_window_store(eng, store, params, batch,
                                                 fl, version)
                else:
                    params = _merge_window(eng, params, snapshots, batch,
                                           fl, version)
            version += len(batch)
            cohort_sizes.append(len(batch))
            for e in batch:
                at[e.client] = update_avg_time(at[e.client], ct[e.client],
                                               e.cost)
                ct[e.client] += 1
                inflight.pop(e.client, None)
                snapshots.pop(e.client, None)

        # Eq. 5/6 window close: last arrival if everyone made it, the
        # full deadline if stragglers are still in flight.
        clock = deadline if q else (batch[-1].finish if batch else deadline)
        tel.gauge("queue.inflight", len(q))

        if rnd % eval_every == 0:
            with tel.span("eval"):
                v_now = trainer.evaluate(params)
            hist.record(time=clock, rnd=rnd, acc=v_now, tier=t_ptr,
                        n_selected=n_sel, n_stragglers=len(q))
            v_prev, v_curr = v_curr, v_now
            if verbose:
                print(f"[feddct_async] r={rnd:4d} t={clock:9.1f}s "
                      f"tier={t_ptr} acc={v_now:.4f} merged="
                      f"{len(batch)} inflight={len(q)}")
            if fl.target_accuracy and v_now >= fl.target_accuracy:
                break
    if not hist.rounds or hist.rounds[-1] != rnd:
        with tel.span("eval"):
            acc = trainer.evaluate(params)
        hist.record(time=clock, rnd=rnd, acc=acc,
                    tier=t_ptr, n_stragglers=len(q))
    hist.meta["mean_cohort"] = (float(np.mean(cohort_sizes))
                                if cohort_sizes else 0.0)
    hist.meta["n_drains"] = len(cohort_sizes)
    # cumulative modeled uplink over every merged update (version counts
    # merges) — telemetry-independent, so the contract meta is always set
    hist.meta["bytes_up"] = version * wb
    if store is not None:
        bt = store.bytes_by_tier()
        hist.meta["store_bytes_hot"] = bt["hot"]
        hist.meta["store_bytes_cold"] = bt["cold"]
        hist.meta["store_bytes_ef"] = bt["ef"]
    run_span.end()
    tel.summarize_into(hist.meta)
    return hist
