"""Divisibility-aware sharding rules (logical name -> PartitionSpec).

Scheme: 2-D "megatron + FSDP" on a ("data", "model") mesh (the multi-pod
mesh adds a leading "pod" axis used for data parallelism only):

  * column-parallel weights (d_in, d_out): ("data", "model")  — output dim
    tensor-sharded, input dim FSDP-sharded over the data axis.
  * row-parallel weights (d_out-producing) like wo / w_down: ("model","data").
  * embeddings (V, d): ("model", "data"); lm head (d, V): ("data","model").
  * MoE expert tables (E, d, ff): expert axis on "model" when E divides it
    (arctic 128 % 16 = 0) — expert parallelism, GSPMD emits the
    all-to-all; otherwise experts stay local and ff is tensor-sharded
    (mixtral E=8: ("data", None, "model") style).
  * norms / biases / gates / conv kernels: replicated.

Every assignment is checked for divisibility against the mesh axis size;
a dim that does not divide falls back to the next candidate or None, so
any (arch x mesh) pair lowers.  Stacked-layer leading axes ("blocks")
are never sharded.
"""

from __future__ import annotations

import re
from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ordered (regex on path, spec template) — first match wins.
# templates use axis names; "F" marks the FSDP (data) axis, "M" model.
_RULES = [
    (r"embed$",                ("M", "F")),
    # head (d, V): vocab tensor-sharded ONLY.  FSDP-sharding d would put
    # the loss matmul's contraction dim on "data" => every logits chunk
    # partial-sums into an all-reduce over the data axis (measured
    # 17 GB/device on llama3.2-1b train_4k — §Perf llama v5).
    (r"head$",                 (None, "M")),
    (r"moe/router$",           ("F", None)),
    (r"moe/w_(gate|up)$",      ("E", "F", "M")),   # (E, d, ff)
    (r"moe/w_down$",           ("E", "M", "F")),   # (E, ff, d)
    (r"(wq|wk|wv|w_gate|w_up|w_in|w_q|w_k|w_v)$", ("F", "M")),
    (r"(wo|w_down|w_out)$",    ("M", "F")),
    (r"slstm/w$",              ("F", "M")),
    (r"slstm/r$",              (None, "F", "M")),
    (r"w_(dt|bc)$",            ("F", "M")),
    (r"w_if$",                 ("F", None)),
    (r"(ln\d?|.*norm|b|bias|scale\d?|dt_bias|A_log|D|b_if|conv_w)$", None),
]


def _axis_ok(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _resolve(template, shape, axes: Dict[str, Any], mesh_sizes: Dict[str, int],
             n_lead_none: int) -> P:
    """Fill a spec template, dropping axes that don't divide."""
    spec = [None] * n_lead_none
    used = set()
    if template is None:
        return P(*([None] * (n_lead_none + len(shape))))
    for dim, slot in zip(shape, template):
        if slot is None:
            spec.append(None)
            continue
        name = {"F": axes.get("fsdp"), "M": axes.get("model"),
                "E": axes.get("model")}[slot]
        size = _mesh_size(name, mesh_sizes)
        if name is not None and name not in used and _axis_ok(dim, size):
            spec.append(name)
            used.add(name)
        else:
            spec.append(None)
    return P(*spec)


def _mesh_size(name, mesh_sizes: Dict[str, int]) -> int:
    if name is None:
        return 0
    if isinstance(name, tuple):
        s = 1
        for n in name:
            s *= mesh_sizes.get(n, 1)
        return s
    return mesh_sizes.get(name, 0)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, mesh: Mesh, *, fsdp: bool = True,
                mode: str = "tp_fsdp"):
    """PartitionSpec pytree matching ``params``.

    mode "tp_fsdp" (default): megatron TP on "model" + FSDP on "data".
    mode "fsdp_only": pure ZeRO-3 — every tensor sharded over the
    combined ("data","model") axes on its first divisible dim; no TP.
    """
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if mode == "fsdp_only":
        return _fsdp_only_specs(params, mesh_sizes)
    axes = {"model": "model" if "model" in mesh_sizes else None,
            "fsdp": "data" if (fsdp and "data" in mesh_sizes) else None}

    def spec_one(path, leaf):
        ps = _path_str(path)
        shape = np.shape(leaf)
        in_blocks = "blocks/" in ps or ps.startswith("blocks")
        lead = 1 if in_blocks else 0
        body = shape[lead:]
        for pat, tmpl in _RULES:
            if re.search(pat, ps):
                if tmpl is None:
                    return P(*([None] * len(shape)))
                if len(tmpl) != len(body):
                    break  # fall through to generic
                return _resolve(tmpl, body, axes, mesh_sizes, lead)
        # generic fallback: model on last divisible dim, fsdp on another
        spec = [None] * len(shape)
        msize = _mesh_size(axes["model"], mesh_sizes)
        fsize = _mesh_size(axes["fsdp"], mesh_sizes)
        for i in range(len(shape) - 1, lead - 1, -1):
            if axes["model"] and _axis_ok(shape[i], msize):
                spec[i] = axes["model"]
                break
        for i in range(lead, len(shape)):
            if spec[i] is None and axes["fsdp"] and _axis_ok(shape[i], fsize):
                spec[i] = axes["fsdp"]
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_one, params)


def _fsdp_only_specs(params, mesh_sizes):
    """ZeRO-3: shard the first dim divisible by the full device count
    (falling back to sub-axis groups) over ("data","model")."""
    cand = [a for a in ("data", "model") if a in mesh_sizes]
    full = int(np.prod([mesh_sizes[a] for a in cand]))

    def spec_one(path, leaf):
        shape = np.shape(leaf)
        ps = _path_str(path)
        in_blocks = "blocks/" in ps or ps.startswith("blocks")
        lead = 1 if in_blocks else 0
        spec = [None] * len(shape)
        for i in range(lead, len(shape)):
            if shape[i] % full == 0 and full > 1:
                spec[i] = tuple(cand)
                break
        else:
            # fall back to the largest single axis that divides some dim
            for ax in cand:
                done = False
                for i in range(lead, len(shape)):
                    if mesh_sizes[ax] > 1 and shape[i] % mesh_sizes[ax] == 0:
                        spec[i] = ax
                        done = True
                        break
                if done:
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_one, params)


def batch_specs(batch_shapes, mesh: Mesh, *, mode: str = "tp_fsdp"):
    """Shard the leading (global-batch) dim over every batch axis that
    divides it; otherwise replicate (long_500k B=1).  In "fsdp_only"
    mode the "model" axis joins the batch axes (pure data parallelism)."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    names = ("pod", "data", "model") if mode == "fsdp_only" \
        else ("pod", "data")
    baxes = tuple(a for a in names if a in mesh_sizes)
    bsize = int(np.prod([mesh_sizes[a] for a in baxes])) if baxes else 1

    def spec_one(leaf):
        shape = leaf.shape if hasattr(leaf, "shape") else np.shape(leaf)
        if len(shape) == 0:
            return P()
        if baxes and shape[0] % bsize == 0:
            return P(baxes, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map(spec_one, batch_shapes)


def decode_state_specs(state, mesh: Mesh):
    """KV/SSM caches: batch dim sharded over data axes when divisible;
    everything else replicated.  Cache layouts: kv k/v (L,B,W,Hkv,Dh),
    pos (L,W); ssm h (L,B,di,n), conv (L,B,K-1,di); xlstm mems."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = tuple(a for a in ("pod", "data") if a in mesh_sizes)
    bsize = int(np.prod([mesh_sizes[a] for a in baxes])) if baxes else 1

    def spec_one(path, leaf):
        ps = _path_str(path)
        shape = np.shape(leaf)
        if ps.endswith("pos") or len(shape) <= 1:
            return P(*([None] * len(shape)))
        # leaf layouts here are stacked over layers: dim0=L, dim1=batch
        if len(shape) >= 2 and baxes and shape[1] % bsize == 0:
            return P(None, baxes, *([None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_one, state)


def named_shardings(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
