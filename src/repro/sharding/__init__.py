from repro.sharding.rules import (
    batch_specs,
    decode_state_specs,
    named_shardings,
    param_specs,
)

__all__ = ["param_specs", "batch_specs", "decode_state_specs",
           "named_shardings"]
