from repro.sharding.rules import (
    param_specs,
    batch_specs,
    decode_state_specs,
    named_shardings,
)

__all__ = ["param_specs", "batch_specs", "decode_state_specs",
           "named_shardings"]
