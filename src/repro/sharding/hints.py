"""Activation sharding hints (GSPMD with_sharding_constraint).

Model code calls ``hint(x, "batch", None, "model", None)`` at layer
boundaries; when a mesh is active (set by the launcher/dry-run via
``set_mesh``) the hint becomes a with_sharding_constraint with every axis
checked for divisibility — axes that don't divide are dropped, so any
(arch x mesh) pair still lowers.  With no mesh set (CPU smoke tests) the
hint is the identity.

"batch" expands to every present data-parallel axis (("pod","data")).
"""

from __future__ import annotations


import jax
import numpy as np
from jax.sharding import PartitionSpec as P

_MESH = None
_SIZES = {}
_FSDP_ONLY = False


def set_mesh(mesh, fsdp_only: bool = False) -> None:
    """Enable hints for ``mesh`` (or disable with None).

    fsdp_only: pure ZeRO-3 data parallelism — the "model" axis joins the
    batch axes and all tensor-parallel hints become no-ops."""
    global _MESH, _SIZES, _FSDP_ONLY
    _MESH = mesh
    _FSDP_ONLY = fsdp_only
    _SIZES = {} if mesh is None else dict(
        zip(mesh.axis_names, mesh.devices.shape))


def get_mesh():
    return _MESH


def axis_size(name: str) -> int:
    """Size of a mesh axis under the active mesh (1 when disabled)."""
    return _size(_expand(name))


def _expand(name):
    if name == "batch":
        names = ("pod", "data", "model") if _FSDP_ONLY else ("pod", "data")
        axes = tuple(a for a in names if a in _SIZES)
        return axes if axes else None
    if isinstance(name, str):
        if _FSDP_ONLY and name == "model":
            return None                    # TP hints no-op in ZeRO-3 mode
        return name if name in _SIZES else None
    return name


def _size(axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, tuple):
        return int(np.prod([_SIZES.get(a, 1) for a in axes]))
    return _SIZES.get(axes, 1)


def hint(x, *axes):
    """Constrain ``x`` (rank must match len(axes)); divisibility-checked."""
    if _MESH is None or not _SIZES:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"hint rank {len(axes)} != tensor rank {x.ndim}")
    spec = []
    for dim, a in zip(x.shape, axes):
        a = _expand(a)
        s = _size(a)
        spec.append(a if (a is not None and s > 1 and dim % s == 0) else None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
