from repro.models.cnn import cnn_forward, cnn_loss, init_cnn
from repro.models.transformer import (
    decode_step,
    forward,
    init_decode_state,
    init_model,
    lm_loss,
)

__all__ = [
    "init_model", "forward", "decode_step", "init_decode_state", "lm_loss",
    "init_cnn", "cnn_forward", "cnn_loss",
]
