from repro.models.transformer import (
    init_model,
    forward,
    decode_step,
    init_decode_state,
    lm_loss,
)
from repro.models.cnn import init_cnn, cnn_forward, cnn_loss

__all__ = [
    "init_model", "forward", "decode_step", "init_decode_state", "lm_loss",
    "init_cnn", "cnn_forward", "cnn_loss",
]
