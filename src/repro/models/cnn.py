"""The paper's FL workloads: small CNNs (MNIST / Fashion-MNIST) and
ResNet8 (CIFAR-10), pure functional JAX.

CNN (paper §5.1): conv3x3(32) -> pool2 -> conv3x3(64) -> pool2 -> flatten
-> FC(512|128) -> FC(10).  ResNet8: 3 stages of 1 basic block each
(16/32/64 channels), as in arXiv:2204.13399.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import dense_init


def _conv_init(key, k, c_in, c_out, dtype):
    w = dense_init(key, (k * k * c_in, c_out), dtype=dtype)
    return w.reshape(k, k, c_in, c_out)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_im2col(x, w, stride=1):
    """Convolution as patch-extraction + GEMM.

    Under a client-axis vmap (the batched FL engine), per-client kernels
    turn ``_conv`` into a grouped convolution — a slow path on CPU.
    Patch extraction has no weights, so vmap folds it into the batch and
    the weighted contraction becomes a batched GEMM, which is an order
    of magnitude faster on the gradient path.  For stride 1 / odd k the
    patches come from shifted slices of the padded input, whose gradient
    is pure pad-and-add (no scatter, another ~5x on the backward pass).
    """
    k, cin, cout = w.shape[0], w.shape[2], w.shape[3]
    if stride != 1 or k % 2 == 0:
        # general case (strided resnet stages) via the patches op;
        # feature axis ordered (cin, kh, kw)
        p = jax.lax.conv_general_dilated_patches(
            x, (k, k), (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        wr = jnp.moveaxis(w, 2, 0).reshape(cin * k * k, cout)
        return p @ wr
    b, h, wd, _ = x.shape
    r = (k - 1) // 2
    xp = jnp.pad(x, ((0, 0), (r, r), (r, r), (0, 0)))
    sl = [xp[:, i:i + h, j:j + wd, :] for i in range(k) for j in range(k)]
    p = jnp.concatenate(sl, axis=-1)     # features ordered (kh, kw, cin)
    return p @ w.reshape(k * k * cin, cout)


def _pool(x):
    b, h, w, c = x.shape
    if h % 2 or w % 2:       # odd spatial dims: generic windowed reduce
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    # 2x2/2 max-pool as reshape+max: identical result, but its gradient
    # avoids XLA's SelectAndScatter (an order of magnitude slower on
    # CPU, and worse under the FL engine's client-axis vmap)
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def init_cnn(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    h, w, c_in = cfg.input_hw
    params: Dict[str, Any] = {}
    if cfg.resnet:
        ks = jax.random.split(key, 2 + 6 * len(cfg.cnn_channels))
        params["stem"] = _conv_init(ks[0], 3, c_in, cfg.cnn_channels[0], dtype)
        c_prev = cfg.cnn_channels[0]
        blocks = []
        ki = 1
        for c in cfg.cnn_channels:
            blk = {
                "conv1": _conv_init(ks[ki], 3, c_prev, c, dtype),
                "conv2": _conv_init(ks[ki + 1], 3, c, c, dtype),
                "scale1": jnp.ones((c,), jnp.float32),
                "scale2": jnp.ones((c,), jnp.float32),
            }
            if c_prev != c:
                blk["proj"] = _conv_init(ks[ki + 2], 1, c_prev, c, dtype)
            blocks.append(blk)
            c_prev = c
            ki += 3
        params["blocks"] = blocks
        params["fc"] = {"w": dense_init(ks[-1], (c_prev, cfg.n_classes),
                                        dtype=dtype),
                        "b": jnp.zeros((cfg.n_classes,), dtype)}
        return params
    # plain CNN
    ks = jax.random.split(key, len(cfg.cnn_channels) + len(cfg.cnn_fc))
    c_prev, ki = c_in, 0
    convs = []
    for c in cfg.cnn_channels:
        convs.append({"w": _conv_init(ks[ki], 3, c_prev, c, dtype),
                      "b": jnp.zeros((c,), dtype)})
        c_prev = c
        ki += 1
    params["convs"] = convs
    flat = (h // 2 ** len(cfg.cnn_channels)) * (w // 2 ** len(cfg.cnn_channels)) * c_prev
    dims = (flat,) + cfg.cnn_fc
    fcs = []
    for a, b in zip(dims[:-1], dims[1:]):
        fcs.append({"w": dense_init(ks[ki], (a, b), dtype=dtype),
                    "b": jnp.zeros((b,), dtype)})
        ki += 1
    params["fcs"] = fcs
    return params


def _norm_act(x, scale):
    # group-norm-ish (batch-independent, FL-friendly: no running stats)
    mu = x.mean(axis=(1, 2), keepdims=True)
    var = x.var(axis=(1, 2), keepdims=True)
    return jax.nn.relu((x - mu) * jax.lax.rsqrt(var + 1e-5) * scale)


def cnn_forward(cfg: ModelConfig, params, images, *, im2col: bool = False):
    """images (B,H,W,C) -> logits (B,n_classes).

    ``im2col=True`` computes every convolution as patches + GEMM — same
    math (to float tolerance), but vmap-friendly; the batched FL engine
    sets it so per-client kernels stay on the fast GEMM path.
    """
    conv = _conv_im2col if im2col else _conv
    x = images
    if cfg.resnet:
        x = conv(x, params["stem"])
        for i, blk in enumerate(params["blocks"]):
            stride = 1 if i == 0 else 2
            h = conv(x, blk["conv1"], stride)
            h = _norm_act(h, blk["scale1"])
            h = conv(h, blk["conv2"])
            sc = x if "proj" not in blk else conv(x, blk["proj"], stride)
            x = _norm_act(h + sc, blk["scale2"])
        x = x.mean(axis=(1, 2))
        return x @ params["fc"]["w"] + params["fc"]["b"]
    for cv in params["convs"]:
        x = jax.nn.relu(conv(x, cv["w"]) + cv["b"])
        x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    for i, fc in enumerate(params["fcs"]):
        x = x @ fc["w"] + fc["b"]
        if i < len(params["fcs"]) - 1:
            x = jax.nn.relu(x)
    return x


def cnn_loss(cfg: ModelConfig, params, batch, *, im2col: bool = False):
    logits = cnn_forward(cfg, params, batch["x"],
                         im2col=im2col).astype(jnp.float32)
    labels = batch["y"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def cnn_accuracy(cfg: ModelConfig, params, xs, ys, batch: int = 512):
    correct = 0
    for i in range(0, xs.shape[0], batch):
        logits = cnn_forward(cfg, params, xs[i:i + batch])
        correct += int((jnp.argmax(logits, -1) == ys[i:i + batch]).sum())
    return correct / xs.shape[0]
