"""Mamba-style selective SSM (diagonal state space) with chunked scan.

Training/prefill uses a chunked parallel scan: the sequence is processed
in chunks of Q steps; within a chunk the (B,Q,d_in,n) discretized tensors
are materialized and combined with an associative scan; the hidden state
(B,d_in,n) is carried across chunks with ``lax.scan``.  Decode is a single
recurrent step.  The Pallas TPU kernel (kernels/ssm_scan.py) implements
the same chunked recurrence with VMEM-resident state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding.hints import hint


def init_ssm(key, d_model: int, n_state: int, expand: int = 2,
             conv_k: int = 4, dtype=jnp.float32):
    d_in = expand * d_model
    ks = jax.random.split(key, 7)
    dt_init = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[5], (d_in,), jnp.float32,
                                   jnp.log(1e-3), jnp.log(1e-1)))))
    return {
        "w_in": dense_init(ks[0], (d_model, 2 * d_in), dtype=dtype),
        "conv_w": dense_init(ks[1], (conv_k, d_in), scale=0.5, dtype=dtype),
        "w_bc": dense_init(ks[2], (d_in, 2 * n_state), dtype=dtype),
        "w_dt": dense_init(ks[3], (d_in, d_in), scale=0.01, dtype=dtype),
        "dt_bias": dt_init.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, n_state + 1, dtype=jnp.float32)
                         )[None, :].repeat(d_in, 0),       # (d_in,n)
        "D": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(ks[4], (d_in, d_model), dtype=dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x (B,S,di), w (K,di).  state (B,K-1,di)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return out, new_state


def _discretize(dt, bc, xc, a_neg, n_state):
    """dt (B,Q,di); bc (B,Q,2n); xc (B,Q,di) -> dA,dBx (B,Q,di,n), C (B,Q,n)."""
    b_in, c_out = bc[..., :n_state], bc[..., n_state:]
    da = jnp.exp(dt[..., None] * a_neg[None, None])            # (B,Q,di,n)
    dbx = (dt * xc)[..., None] * b_in[:, :, None, :]
    return da, dbx, c_out


def _chunk_scan(da, dbx, h0):
    """Associative scan of h_t = da_t*h + dbx_t within a chunk.

    da, dbx: (B,Q,di,n) f32; h0: (B,di,n).  Returns hs (B,Q,di,n), h_end.
    """
    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, bl * ar + br
    a_cum, b_cum = jax.lax.associative_scan(combine, (da, dbx), axis=1)
    hs = b_cum + a_cum * h0[:, None]
    return hs, hs[:, -1]


def ssm_core(p, xc, dt, bc, h0, n_state: int, chunk: int = 256):
    """Chunked selective scan.  xc,dt (B,S,di); bc (B,S,2n)."""
    b, s, di = xc.shape
    a_neg = -jnp.exp(p["A_log"])                               # (di,n)
    q = min(chunk, s)
    if s % q:
        q = s
    nc = s // q

    def body(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * q, q, 1)
        da, dbx, c_out = _discretize(
            sl(dt).astype(jnp.float32), sl(bc).astype(jnp.float32),
            sl(xc).astype(jnp.float32), a_neg, n_state)
        da = hint(da, "batch", None, "model", None)
        dbx = hint(dbx, "batch", None, "model", None)
        hs, h_end = _chunk_scan(da, dbx, h)
        yc = jnp.einsum("bqdn,bqn->bqd", hs, c_out.astype(jnp.float32))
        return h_end, yc

    h0 = jnp.zeros((b, di, n_state), jnp.float32) if h0 is None else h0
    h_end, ys = jax.lax.scan(body, h0, jnp.arange(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)
    return y.astype(xc.dtype), h_end


def ssm_forward(p, x, *, n_state: int, chunk: int = 256, state=None):
    """Full layer.  x (B,S,d_model) -> y, new_state (for decode handoff).

    state = {"h": (B,di,n), "conv": (B,K-1,di)} or None.
    """
    b, s, d = x.shape
    xz = x @ p["w_in"]
    di = xz.shape[-1] // 2
    xp, z = xz[..., :di], xz[..., di:]
    xp = hint(xp, "batch", None, "model")   # channel-parallel SSM heads
    conv_state = None if state is None else state["conv"]
    xp, new_conv = _causal_conv(xp, p["conv_w"], conv_state)
    xp = jax.nn.silu(xp)
    dt = jax.nn.softplus(xp @ p["w_dt"] + p["dt_bias"].astype(xp.dtype))
    bc = xp @ p["w_bc"]
    h0 = None if state is None else state["h"]
    y, h_end = ssm_core(p, xp, dt, bc, h0, n_state, chunk)
    y = y + p["D"].astype(y.dtype) * xp
    out = (y * jax.nn.silu(z)) @ p["w_out"]
    new_state = {"h": h_end, "conv": new_conv}
    return out, new_state


def init_ssm_state(batch: int, d_model: int, n_state: int, expand: int,
                   conv_k: int, dtype=jnp.bfloat16):
    di = expand * d_model
    return {"h": jnp.zeros((batch, di, n_state), jnp.float32),
            "conv": jnp.zeros((batch, conv_k - 1, di), dtype)}


def ssm_decode_step(p, x, state, *, n_state: int):
    """x (B,1,d_model) single step."""
    return ssm_forward(p, x, n_state=n_state, chunk=1, state=state)
