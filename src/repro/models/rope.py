"""Rotary position embeddings."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                     # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
