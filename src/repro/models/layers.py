"""Shared functional building blocks (no flax — plain pytrees)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-ish, like maxtext defaults)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def activate(h_gate, h_up, kind: str):
    """Fused MLP activation.  For non-gated kinds ``h_gate`` is the input."""
    if kind == "swiglu":
        return jax.nn.silu(h_gate) * h_up
    if kind == "squared_relu":
        r = jax.nn.relu(h_gate)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h_gate)
    if kind == "relu":
        return jax.nn.relu(h_gate)
    raise ValueError(kind)


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
         "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype)}
    if kind == "swiglu":
        p["w_gate"] = dense_init(ks[0], (d_model, d_ff), dtype=dtype)
    return p


def mlp(p, x, kind: str):
    if kind == "swiglu":
        h = activate(x @ p["w_gate"], x @ p["w_up"], kind)
    else:
        h = activate(x @ p["w_up"], None, kind)
    return h @ p["w_down"]


def take_embedding(table, ids):
    return jnp.take(table, ids, axis=0)
