"""Model assembly: init / forward / decode for every assigned family.

Layers are stacked (leading L axis) and traversed with ``lax.scan`` so
96-layer configs compile in bounded time/memory; ``remat=True`` wraps the
block body in ``jax.checkpoint``.  Decode carries per-layer caches through
the same scan.

Families:
  dense / vlm : GQA + RoPE + (SwiGLU | squared-ReLU | GeLU) MLP, optional SWA
  audio       : bidirectional encoder (frame embeddings in, codebook out)
  moe         : GQA + top-k MoE FFN (sort-based capacity dispatch)
  hybrid      : parallel attention + Mamba heads per layer (Hymba)
  ssm         : alternating mLSTM / sLSTM pairs (xLSTM)
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (dense_init, embed_init, init_mlp, mlp,
                                 rms_norm, take_embedding)
from repro.models.rope import apply_rope
from repro.sharding.hints import hint


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.q_dim), dtype=dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dtype=dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, cfg.d_model), dtype=dtype),
    }


def _init_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    fam = cfg.family
    if fam == "ssm":  # xLSTM pair
        return {
            "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
            "mlstm": xlstm_lib.init_mlstm(ks[0], cfg.d_model, cfg.n_heads,
                                          cfg.proj_factor, dtype=dtype),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
            "slstm": xlstm_lib.init_slstm(ks[1], cfg.d_model, cfg.n_heads,
                                          dtype=dtype),
            "ln3": jnp.zeros((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(ks[2], cfg.d_model, int(cfg.d_model * 4 / 3),
                            "gelu", dtype=dtype),
        }
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": _init_attn(ks[0], cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if fam == "moe":
        p["moe"] = moe_lib.init_moe(
            ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.activation,
            dense_residual=cfg.moe_dense_residual,
            dense_ff=cfg.moe_dense_ff, dtype=dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation,
                            dtype=dtype)
    if fam == "hybrid":
        p["ssm"] = ssm_lib.init_ssm(ks[2], cfg.d_model, cfg.ssm_state,
                                    cfg.ssm_expand, cfg.ssm_conv, dtype=dtype)
    return p


def init_model(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    n_stack = _n_stack(cfg)
    block_keys = jax.random.split(k_blocks, n_stack)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(block_keys)
    params = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                    dtype=dtype)
    return params


def _n_stack(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        assert cfg.num_layers % 2 == 0, "xLSTM pairs need even num_layers"
        return cfg.num_layers // 2
    return cfg.num_layers


# ---------------------------------------------------------------------------
# Block apply (full sequence)
# ---------------------------------------------------------------------------

def _attn_apply(p, cfg: ModelConfig, x, positions, *, window: int,
                chunk_q: int, chunk_kv: int, context_parallel: str = "auto"):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = hint(q, "batch", None, "model", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attn_lib.attention(q, k, v, causal=cfg.causal, window=window,
                           chunk_q=chunk_q, chunk_kv=chunk_kv,
                           softcap=cfg.attn_logit_softcap,
                           context_parallel=context_parallel)
    o = hint(o, "batch", None, "model", None)
    return o.reshape(b, s, cfg.q_dim) @ p["wo"]


def _block_apply(p, cfg: ModelConfig, x, positions, *, window: int,
                 chunk_q: int, chunk_kv: int, ssm_chunk: int,
                 moe_group: int, context_parallel: str = "auto"):
    """Returns (x, aux_loss)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    if fam == "ssm":
        h, _ = xlstm_lib.mlstm_block(p["mlstm"], rms_norm(x, p["ln1"]),
                                     cfg.n_heads, chunk=ssm_chunk)
        x = x + h
        h, _ = xlstm_lib.slstm_block(p["slstm"], rms_norm(x, p["ln2"]),
                                     cfg.n_heads)
        x = x + h
        x = x + mlp(p["mlp"], rms_norm(x, p["ln3"]), "gelu")
        return x, aux

    a_in = rms_norm(x, p["ln1"])
    a_out = _attn_apply(p["attn"], cfg, a_in, positions, window=window,
                        chunk_q=chunk_q, chunk_kv=chunk_kv,
                        context_parallel=context_parallel)
    if fam == "hybrid":
        s_out, _ = ssm_lib.ssm_forward(p["ssm"], a_in, n_state=cfg.ssm_state,
                                       chunk=ssm_chunk)
        a_out = 0.5 * (a_out + s_out)
    x = x + a_out
    m_in = rms_norm(x, p["ln2"])
    if fam == "moe":
        y, aux = moe_lib.moe_ffn(
            p["moe"], m_in, top_k=cfg.top_k, activation=cfg.activation,
            capacity_factor=cfg.moe_capacity_factor, group_size=moe_group,
            dense_residual=cfg.moe_dense_residual)
    else:
        y = mlp(p["mlp"], m_in, cfg.activation)
    return x + y, aux


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, batch):
    if "frames" in batch:                      # audio stub frontend
        return batch["frames"].astype(params["embed"].dtype)
    return take_embedding(params["embed"], batch["tokens"])


def forward(cfg: ModelConfig, params, batch, *, window: int = -1,
            chunk_q: int = 512, chunk_kv: int = 1024, ssm_chunk: int = 256,
            moe_group: int = 0, remat: bool = False, return_hidden=False,
            context_parallel: str = "auto", seq_parallel: bool = False,
            remat_policy: str = "full"):
    """Full-sequence forward.  Returns (logits, aux_loss).

    ``window``: -1 => use cfg.sliding_window; 0 => force full attention;
    >0 => override (used for the long_500k SWA variants of dense archs).
    ``seq_parallel``: shard the residual stream's sequence dim over the
    "model" axis between blocks (megatron sequence parallelism — GSPMD
    turns the per-block all-reduces into all-gather + reduce-scatter).
    """
    x = embed_inputs(cfg, params, batch)
    res_hint = (lambda t: hint(t, "batch", "model", None)) if seq_parallel \
        else (lambda t: hint(t, "batch", None, None))
    x = res_hint(x)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    w = cfg.sliding_window if window < 0 else window

    def body(carry, p_l):
        xc, aux = carry
        xc, a = _block_apply(p_l, cfg, xc, positions, window=w,
                             chunk_q=chunk_q, chunk_kv=chunk_kv,
                             ssm_chunk=ssm_chunk, moe_group=moe_group,
                             context_parallel=context_parallel)
        xc = res_hint(xc)
        return (xc, aux + a), None

    if remat and remat_policy == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        body_fn = jax.checkpoint(body)
    else:
        body_fn = body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, aux
    head = params.get("head", None)
    if head is None:
        head = params["embed"].T
    return x @ head, aux


def lm_loss(cfg: ModelConfig, params, batch, *, loss_chunk: int = 512,
            **fwd_kw):
    """Sequence-chunked cross-entropy (never materializes (B,S,V) f32).

    Causal LM: predict token t+1 from t.  Audio (encoder): labels given
    per frame, no shift.  Returns (loss, aux).
    """
    hidden, aux = forward(cfg, params, batch, return_hidden=True, **fwd_kw)
    head = params.get("head", None)
    if head is None:
        head = params["embed"].T
    if cfg.is_encoder_only:
        targets = batch["labels"]
        hs, tg = hidden, targets
    else:
        tokens = batch["tokens"]
        hs, tg = hidden[:, :-1], tokens[:, 1:]
    b, s, d = hs.shape
    c = min(loss_chunk, s)
    if s % c:
        c = s
    hs = hs.reshape(b, s // c, c, d)
    tg = tg.reshape(b, s // c, c)

    @jax.checkpoint  # recompute the (B,c,V) logits in backward: the whole
    def chunk_ce(carry, inp):  # point of chunking is never storing them
        h, t = inp                          # (B,c,d), (B,c)
        logits = (h @ head).astype(jnp.float32)
        logits = hint(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(chunk_ce, jnp.zeros((), jnp.float32),
                            (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(tg, 1, 0)))
    loss = total / (b * s)
    return loss + 0.01 * aux, aux


# ---------------------------------------------------------------------------
# Decode (single token with per-layer caches)
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    fam = cfg.family
    if fam == "ssm":
        return {"m": xlstm_lib.init_mlstm_state(batch, cfg.d_model,
                                                cfg.n_heads, cfg.proj_factor,
                                                dtype=dtype),
                "s": xlstm_lib.init_slstm_state(batch, cfg.d_model)}
    kv_len = cache_len
    if cfg.sliding_window:
        kv_len = min(cache_len, cfg.sliding_window)
    c = {"kv": attn_lib.init_kv_cache(batch, kv_len, cfg.n_kv_heads,
                                      cfg.head_dim, dtype)}
    if fam == "hybrid":
        c["ssm"] = ssm_lib.init_ssm_state(batch, cfg.d_model, cfg.ssm_state,
                                          cfg.ssm_expand, cfg.ssm_conv, dtype)
    return c


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, window: int = -1):
    """Stacked per-layer caches + position counter."""
    w = cfg.sliding_window if window < 0 else window
    if w and w > 0:
        kv_len = min(cache_len, w)
    else:
        kv_len = cache_len
    template = _layer_cache(cfg, batch, kv_len if w else cache_len, dtype)
    n_stack = _n_stack(cfg)
    caches = jax.tree_util.tree_map(
        lambda t: jnp.zeros((n_stack,) + t.shape, t.dtype), template)
    caches = _refill_pos(caches)
    return {"layers": caches, "pos": jnp.zeros((), jnp.int32)}


def _refill_pos(caches):
    """kv position slots start at -1 (invalid) and xLSTM stabilizers at
    NEG, not 0 — re-fill them after the zeros-stacking above."""
    def fix_dict(c):
        if isinstance(c, dict):
            out = {}
            for k, v in c.items():
                if k == "pos" and isinstance(v, jnp.ndarray):
                    out[k] = jnp.full_like(v, -1)
                elif k == "m" and isinstance(v, tuple):
                    out[k] = (v[0], v[1], jnp.full_like(v[2], xlstm_lib.NEG))
                elif k == "mem" and isinstance(v, tuple):
                    out[k] = (v[0], v[1], jnp.full_like(v[2], xlstm_lib.NEG))
                else:
                    out[k] = fix_dict(v)
            return out
        if isinstance(c, tuple):
            return tuple(fix_dict(v) for v in c)
        return c
    return fix_dict(caches)


def _block_decode(p, cfg: ModelConfig, x, cache, pos, *, window: int):
    fam = cfg.family
    if fam == "ssm":
        h, m_new = xlstm_lib.mlstm_block(p["mlstm"], rms_norm(x, p["ln1"]),
                                         cfg.n_heads, state=cache["m"],
                                         chunk=1)
        x = x + h
        h, s_new = xlstm_lib.slstm_block(p["slstm"], rms_norm(x, p["ln2"]),
                                         cfg.n_heads, state=cache["s"])
        x = x + h
        x = x + mlp(p["mlp"], rms_norm(x, p["ln3"]), "gelu")
        return x, {"m": m_new, "s": s_new}

    b = x.shape[0]
    a_in = rms_norm(x, p["ln1"])
    pa = p["attn"]
    q = (a_in @ pa["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = (a_in @ pa["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = (a_in @ pa["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    posb = pos[None, None] if pos.ndim == 0 else pos
    q = apply_rope(q, jnp.asarray(pos)[None, None], cfg.rope_theta)
    k = apply_rope(k, jnp.asarray(pos)[None, None], cfg.rope_theta)
    kv = attn_lib.update_kv_cache(cache["kv"], k, v, pos)
    o = attn_lib.decode_attention(q, kv, pos, window=window,
                                  softcap=cfg.attn_logit_softcap)
    a_out = o.reshape(b, 1, cfg.q_dim) @ pa["wo"]
    new_cache = {"kv": kv}
    if fam == "hybrid":
        s_out, ssm_new = ssm_lib.ssm_decode_step(
            p["ssm"], a_in, cache["ssm"], n_state=cfg.ssm_state)
        a_out = 0.5 * (a_out + s_out)
        new_cache["ssm"] = ssm_new
    x = x + a_out
    m_in = rms_norm(x, p["ln2"])
    if fam == "moe":
        y, _ = moe_lib.moe_ffn(
            p["moe"], m_in, top_k=cfg.top_k, activation=cfg.activation,
            capacity_factor=cfg.moe_capacity_factor,
            dense_residual=cfg.moe_dense_residual)
    else:
        y = mlp(p["mlp"], m_in, cfg.activation)
    return x + y, new_cache


def decode_step(cfg: ModelConfig, params, state, tokens, *, window: int = -1):
    """One decode step.  tokens (B,1) int32 (or (B,1,d) frames).

    Returns (logits (B,1,V), new_state).
    """
    if cfg.is_encoder_only:
        raise ValueError(f"{cfg.arch_id} is encoder-only: no decode step")
    w = cfg.sliding_window if window < 0 else window
    x = take_embedding(params["embed"], tokens)
    pos = state["pos"]

    def body(xc, layer):
        p_l, c_l = layer
        xc, c_new = _block_decode(p_l, cfg, xc, c_l, pos, window=w)
        return xc, c_new

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], state["layers"]))
    x = rms_norm(x, params["final_norm"])
    head = params.get("head", None)
    if head is None:
        head = params["embed"].T
    logits = x @ head
    return logits, {"layers": new_caches, "pos": pos + 1}
