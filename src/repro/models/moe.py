"""Top-k token-choice MoE with sort-based capacity dispatch (GShard-style
drops, Megablocks-style sort) — static shapes, pjit/GSPMD friendly.

Tokens are processed in groups (default: one group per batch row).  Within
a group: route -> stable-sort by expert -> take the first ``capacity``
tokens per expert -> batched expert FFN einsum (experts shardable over the
"model" mesh axis => GSPMD emits the all-to-all) -> combine by gate weight.
Dropped tokens pass through the residual only (standard capacity-drop).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding.hints import axis_size, hint


def init_moe(key, d_model: int, d_ff: int, n_experts: int, activation: str,
             dense_residual: bool = False, dense_ff: int = 0,
             dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), dtype=jnp.float32),
        "w_up": dense_init(ks[2], (n_experts, d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[3], (n_experts, d_ff, d_model), dtype=dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = dense_init(ks[1], (n_experts, d_model, d_ff), dtype=dtype)
    if dense_residual:
        from repro.models.layers import init_mlp
        p["dense_mlp"] = init_mlp(ks[4], d_model, dense_ff or d_ff,
                                  activation, dtype=dtype)
    return p


def capacity_for(group_size: int, top_k: int, n_experts: int,
                 factor: float) -> int:
    c = int(math.ceil(group_size * top_k / n_experts * factor))
    c = max(c, 1)
    return min(c, group_size * top_k)


def _route_group(x, router_w, top_k: int, capacity: int):
    """x (S,d) -> dispatch indices for one token group.

    Returns:
      src_token  (E,C)  token index feeding each expert slot
      slot_valid (E,C)  slot occupancy
      tok_slot   (S,k)  flat slot id for each token's k-th choice
      tok_keep   (S,k)  survived capacity
      gates      (S,k)  renormalized gate weights
      probs      (S,E)  full router probabilities (for aux loss)
    """
    s, _ = x.shape
    e = router_w.shape[1]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (S,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # (S,k)
    gates = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(-1)                            # (S*k,)
    order = jnp.argsort(flat_e, stable=True)                   # (S*k,)
    sorted_e = flat_e[order]
    first_of = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(s * top_k) - first_of              # rank in expert
    inv = jnp.argsort(order, stable=True)
    pos = pos_sorted[inv].reshape(s, top_k)
    tok_keep = pos < capacity
    tok_slot = expert_idx * capacity + jnp.minimum(pos, capacity - 1)

    offsets = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    counts = jnp.searchsorted(sorted_e, jnp.arange(e), side="right") - offsets
    slot_rank = jnp.arange(capacity)[None, :]
    slot_valid = slot_rank < jnp.minimum(counts, capacity)[:, None]  # (E,C)
    src_sorted = jnp.clip(offsets[:, None] + slot_rank, 0, s * top_k - 1)
    src_token = order[src_sorted] // top_k                     # (E,C)
    return src_token, slot_valid, tok_slot, tok_keep, gates, probs


def moe_ffn(p, x, *, top_k: int, activation: str, capacity_factor: float,
            group_size: int = 0, dense_residual: bool = False):
    """x (B,S,d) -> (B,S,d), aux_loss (scalar f32)."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    g = group_size or s
    tokens = x.reshape(-1, d)
    n_tok = tokens.shape[0]
    if n_tok % g:
        g = n_tok                       # single group fallback (decode etc.)
    groups = tokens.reshape(-1, g, d)   # (G, S_g, d)
    cap = capacity_for(g, top_k, e, capacity_factor)

    src_token, slot_valid, tok_slot, tok_keep, gates, probs = jax.vmap(
        lambda xx: _route_group(xx, p["router"], top_k, cap))(groups)

    # dispatch: (G,E,C,d)
    x_slots = jax.vmap(lambda xx, idx: xx[idx])(groups, src_token)
    x_slots = x_slots * slot_valid[..., None].astype(x_slots.dtype)
    # expert-parallel layout: E over "model" when divisible (arctic 128/16)
    # — this constraint IS the all-to-all; otherwise ff is tensor-sharded.
    x_slots = hint(x_slots, "batch", "model", None, None)

    # expert FFN: experts shardable over "model" axis
    if activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x_slots, p["w_gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", x_slots, p["w_up"])
    else:
        h = jnp.einsum("gecd,edf->gecf", x_slots, p["w_up"])
        if activation == "squared_relu":
            r = jax.nn.relu(h)
            h = r * r
        else:
            h = jax.nn.gelu(h)
    expert_parallel = e % max(axis_size("model"), 1) == 0
    if expert_parallel:
        h = hint(h, "batch", "model", None, None)
    else:
        h = hint(h, "batch", None, None, "model")
    y_slots = jnp.einsum("gecf,efd->gecd", h, p["w_down"])     # (G,E,C,d)
    y_slots = hint(y_slots, "batch", "model", None, None)

    # combine: gather each token's k slots
    y_flat = y_slots.reshape(groups.shape[0], e * cap, d)
    y_tok = jax.vmap(lambda yy, idx: yy[idx])(y_flat, tok_slot)  # (G,S,k,d)
    w = (gates * tok_keep).astype(y_tok.dtype)                  # (G,S,k)
    y = jnp.einsum("gskd,gsk->gsd", y_tok, w)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(axis=(0, 1))                                # (E,)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), e).mean(axis=(0, 1))
    aux = e * jnp.sum(top1 * me)

    y = y.reshape(b, s, d).astype(x.dtype)
    if dense_residual:
        from repro.models.layers import mlp
        y = y + mlp(p["dense_mlp"], x, activation)
    return y, aux.astype(jnp.float32)
