"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM
[arXiv:2405.04517].

mLSTM: matrix-memory LSTM with exponential gating.  Training/prefill uses
the chunkwise form — intra-chunk quadratic (attention-like, (B,H,Q,Q)),
inter-chunk recurrent state (C (B,H,Dh,Dh), n (B,H,Dh), stabilizer m
(B,H)) carried with lax.scan.  All gate math is stabilized in log space.

sLSTM: scalar-memory LSTM with exponential gating and block-diagonal
recurrent weights (per head) — inherently sequential, lax.scan over time.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm
from repro.models.ssm import _causal_conv
from repro.sharding.hints import hint

NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, n_heads: int, proj_factor: float = 2.0,
               conv_k: int = 4, dtype=jnp.float32):
    di = int(proj_factor * d_model)
    di -= di % n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d_model, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (conv_k, di), scale=0.5, dtype=dtype),
        "w_q": dense_init(ks[2], (di, di), dtype=dtype),
        "w_k": dense_init(ks[3], (di, di), dtype=dtype),
        "w_v": dense_init(ks[4], (di, di), dtype=dtype),
        "w_if": dense_init(ks[5], (di, 2 * n_heads), scale=0.01, dtype=jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((n_heads,)),
                                 jnp.linspace(3.0, 6.0, n_heads)]).astype(jnp.float32),
        "hnorm": jnp.zeros((di,), jnp.float32),
        "w_down": dense_init(ks[6], (di, d_model), dtype=dtype),
    }


def _cummax(x, axis):
    return jax.lax.associative_scan(jnp.maximum, x, axis=axis)


def mlstm_core(q, k, v, logi, logf, carry, chunk: int = 256):
    """q,k,v (B,H,S,Dh) f32; logi,logf (B,H,S) f32.

    carry: (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H)) — semantics: true state is
    (C,n) * exp(m).  Returns h (B,H,S,Dh) and final carry.
    """
    bsz, hh, s, dh = q.shape
    k = k / math.sqrt(dh)
    qc = min(chunk, s)
    if s % qc:
        qc = s
    nc = s // qc
    if carry is None:
        carry = (jnp.zeros((bsz, hh, dh, dh), jnp.float32),
                 jnp.zeros((bsz, hh, dh), jnp.float32),
                 jnp.full((bsz, hh), NEG, jnp.float32))

    tri = jnp.tril(jnp.ones((qc, qc), bool))

    def body(car, idx):
        ctil, ntil, m = car
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * qc, qc, 2)
        qb, kb, vb = sl(q), sl(k), sl(v)
        li, lf = sl(logi), sl(logf)
        b_cum = jnp.cumsum(lf, axis=-1)                      # (B,H,Q)
        g = li - b_cum
        m_intra = b_cum + _cummax(g, axis=-1)
        m_t = jnp.maximum(m[..., None] + b_cum, m_intra)     # (B,H,Q)

        inter_scale = jnp.exp(m[..., None] + b_cum - m_t)    # (B,H,Q)
        inter_num = inter_scale[..., None] * jnp.einsum(
            "bhqd,bhde->bhqe", qb, ctil)
        dmat = (b_cum[..., :, None] - b_cum[..., None, :]
                + li[..., None, :] - m_t[..., None])         # (B,H,Q,Q)
        w = jnp.exp(jnp.where(tri, dmat, NEG))
        qk = jnp.einsum("bhqd,bhjd->bhqj", qb, kb)
        wqk = w * qk
        num = inter_num + jnp.einsum("bhqj,bhjd->bhqd", wqk, vb)
        den = (inter_scale * jnp.einsum("bhqd,bhd->bhq", qb, ntil)
               + wqk.sum(-1))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # chunk-end state update
        b_last = b_cum[..., -1]
        m_new = jnp.maximum(m + b_last, b_last + g.max(-1))
        wj = jnp.exp(g + (b_last - m_new)[..., None])        # (B,H,Q)
        decay = jnp.exp(m + b_last - m_new)
        ctil = (decay[..., None, None] * ctil
                + jnp.einsum("bhj,bhjd,bhje->bhde", wj, kb, vb))
        ntil = decay[..., None] * ntil + jnp.einsum("bhj,bhjd->bhd", wj, kb)
        return (ctil, ntil, m_new), h

    car, hs = jax.lax.scan(body, carry, jnp.arange(nc))
    h = jnp.moveaxis(hs, 0, 2).reshape(bsz, hh, s, dh)
    return h, car


def mlstm_block(p, x, n_heads: int, state=None, chunk: int = 256):
    """x (B,S,d_model) -> y, new_state.  Residual applied by caller."""
    b, s, d = x.shape
    xz = x @ p["w_up"]
    di = xz.shape[-1] // 2
    xi, z = xz[..., :di], xz[..., di:]
    xi = hint(xi, "batch", None, "model")
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xi, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    dh = di // n_heads
    to_heads = lambda t: jnp.moveaxis(
        t.reshape(b, s, n_heads, dh), 1, 2).astype(jnp.float32)
    q = to_heads(xc @ p["w_q"])
    k = to_heads(xc @ p["w_k"])
    v = to_heads(xi @ p["w_v"])
    gates = (xc.astype(jnp.float32) @ p["w_if"] + p["b_if"])   # (B,S,2H)
    logi = jnp.moveaxis(gates[..., :n_heads], 1, 2)
    logf = jax.nn.log_sigmoid(jnp.moveaxis(gates[..., n_heads:], 1, 2))
    carry = None if state is None else state["mem"]
    h, car = mlstm_core(q, k, v, logi, logf, carry, chunk)
    h = jnp.moveaxis(h, 2, 1).reshape(b, s, di).astype(x.dtype)
    h = rms_norm(h, p["hnorm"])
    y = (h * jax.nn.silu(z)) @ p["w_down"]
    return y, {"mem": car, "conv": new_conv}


def init_mlstm_state(batch: int, d_model: int, n_heads: int,
                     proj_factor: float, conv_k: int = 4, dtype=jnp.bfloat16):
    di = int(proj_factor * d_model)
    di -= di % n_heads
    dh = di // n_heads
    return {
        "mem": (jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
                jnp.zeros((batch, n_heads, dh), jnp.float32),
                jnp.full((batch, n_heads), NEG, jnp.float32)),
        "conv": jnp.zeros((batch, conv_k - 1, di), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, n_heads: int, dtype=jnp.float32):
    dh = d_model // n_heads
    ks = jax.random.split(key, 4)
    fb = jnp.tile(jnp.linspace(3.0, 6.0, n_heads)[:, None], (1, dh)).reshape(-1)
    return {
        "w": dense_init(ks[0], (d_model, 4 * d_model), dtype=dtype),
        "r": dense_init(ks[1], (n_heads, dh, 4 * dh), scale=0.1, dtype=dtype),
        "b": jnp.concatenate([jnp.zeros((d_model,)),    # z
                              jnp.zeros((d_model,)),    # i
                              fb,                       # f (positive bias)
                              jnp.zeros((d_model,))]).astype(jnp.float32),
        "hnorm": jnp.zeros((d_model,), jnp.float32),
    }


def slstm_scan(p, x, n_heads: int, state=None):
    """x (B,S,d) -> h (B,S,d), new state.  Sequential over time."""
    b, s, d = x.shape
    dh = d // n_heads
    zx = x @ p["w"] + p["b"].astype(x.dtype)                  # (B,S,4d)
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = {"c": zeros, "n": zeros, "m": jnp.full((b, d), NEG, jnp.float32),
                 "h": zeros}

    def step(st, zx_t):
        hp = st["h"].reshape(b, n_heads, dh).astype(p["r"].dtype)
        rh = jnp.einsum("bhd,hde->bhe", hp, p["r"]).reshape(b, 4 * d)
        pre = zx_t.astype(jnp.float32) + rh.astype(jnp.float32)
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + st["m"], it)
        i = jnp.exp(it - m_new)
        f = jnp.exp(logf + st["m"] - m_new)
        c = f * st["c"] + i * jnp.tanh(zt)
        n = f * st["n"] + i
        h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
        return {"c": c, "n": n, "m": m_new, "h": h}, h

    new_state, hs = jax.lax.scan(step, state, jnp.moveaxis(zx, 0, 1))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    return h, new_state


def slstm_block(p, x, n_heads: int, state=None):
    h, new_state = slstm_scan(p, x, n_heads, state)
    h = rms_norm(h, p["hnorm"])
    return h, new_state


def init_slstm_state(batch: int, d_model: int):
    z = jnp.zeros((batch, d_model), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, d_model), NEG, jnp.float32),
            "h": z}
