"""GQA attention: naive, flash-style chunked, banded sliding-window, decode.

Layout: q (B,S,H,D); k/v enter as (B,T,Hkv,D) and are repeated to full H
before the score computation ("full-head" layout).  This keeps the head
axis a single shardable dimension — under the production mesh the head
axis carries the "model" axis (megatron-style tensor parallelism) and
each shard sees only its q heads plus the matching repeated KV slices.
Sharding hints are divisibility-checked no-ops without a mesh.

Three execution paths:
  * ``naive_attention``   — O(S*T) materialized scores; smoke tests / oracle.
  * ``chunked_attention`` — flash-style online softmax, outer scan over Q
    chunks, inner scan over KV chunks; bounded memory; the lowering path
    for big shapes.  Causal masking is per block; fully-masked blocks are
    still computed (see EXPERIMENTS.md §Perf for the block-skip variant).
  * ``banded_attention``  — true O(S*W) sliding window: each Q chunk
    dynamic-slices only the KV chunks inside its band.
The Pallas TPU kernel (kernels/flash_attention.py) implements the same
online-softmax algorithm with explicit VMEM BlockSpecs.

All softmax math is float32; inputs/outputs keep their dtype.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding.hints import axis_size, hint

NEG_INF = -1e30

# perf-iteration toggle (EXPERIMENTS.md §Perf): head_dim-sharded decode
# attention for archs whose head count doesn't divide the model axis.
DECODE_HEADDIM_SHARD = True


def repeat_kv(k, n_heads: int):
    """(B,T,Hkv,D) -> (B,T,H,D) by repeating each kv head H/Hkv times."""
    rep = n_heads // k.shape[2]
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """(Sq,Tk) additive bias from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(m, 0.0, NEG_INF)


def _softcap(s, softcap: float):
    return jnp.tanh(s / softcap) * softcap if softcap > 0 else s


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    softcap: float = 0.0):
    """q (B,S,H,D); k/v (B,T,H,D) already head-expanded."""
    b, s, h, d = q.shape
    s_ = jnp.einsum("bqhd,bthd->bhqt", q, k,
                    preferred_element_type=jnp.float32) / math.sqrt(d)
    s_ = _softcap(s_, softcap)
    q_pos = jnp.arange(s) + q_offset
    k_pos = jnp.arange(k.shape[1])
    s_ = s_ + _mask_bias(q_pos, k_pos, causal, window)[None, None]
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def _flash_inner(qb, k, v, q_pos, causal, window, chunk_kv, scale, softcap):
    """Online softmax over KV chunks for one Q chunk.

    qb: (B,Sq,H,D) f32; k/v (B,T,H,D).  Returns (B,Sq,H,D) f32.
    """
    b, sq, h, d = qb.shape
    t = k.shape[1]
    n_blocks = t // chunk_kv

    def body(carry, blk):
        acc, m_run, l_run = carry
        kb = jax.lax.dynamic_slice_in_dim(k, blk * chunk_kv, chunk_kv, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, blk * chunk_kv, chunk_kv, 1)
        s_ = jnp.einsum("bqhd,bthd->bhqt", qb, kb,
                        preferred_element_type=jnp.float32) * scale
        s_ = _softcap(s_, softcap)
        k_pos = blk * chunk_kv + jnp.arange(chunk_kv)
        s_ = s_ + _mask_bias(q_pos, k_pos, causal, window)[None, None]
        s_ = hint(s_, "batch", "model", None, None)
        m_new = jnp.maximum(m_run, s_.max(axis=-1))
        p = jnp.exp(s_ - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqt,bthd->bhqd", p, vb.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(
        body, (acc0, m0, l0), jnp.arange(n_blocks))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2)                     # (B,Sq,H,D)


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      chunk_q=512, chunk_kv=1024, softcap: float = 0.0):
    b, s, h, d = q.shape
    t = k.shape[1]
    chunk_q = min(chunk_q, s)
    chunk_kv = min(chunk_kv, t)
    if s % chunk_q or t % chunk_kv:
        raise ValueError(f"seq {s}/{t} not divisible by chunks "
                         f"{chunk_q}/{chunk_kv}")
    scale = 1.0 / math.sqrt(d)

    def q_block(blk):
        qb = jax.lax.dynamic_slice_in_dim(q, blk * chunk_q, chunk_q, 1)
        q_pos = q_offset + blk * chunk_q + jnp.arange(chunk_q)
        return _flash_inner(qb, k, v, q_pos, causal, window, chunk_kv,
                            scale, softcap)

    _, outs = jax.lax.scan(lambda c, i: (c, q_block(i)), None,
                           jnp.arange(s // chunk_q))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)
    return out.astype(q.dtype)


def banded_attention(q, k, v, *, window: int, causal=True, q_offset=0,
                     chunk_q=512, chunk_kv=1024, softcap: float = 0.0):
    """True O(S*W) sliding-window attention via per-chunk KV band gather."""
    b, s, h, d = q.shape
    t = k.shape[1]
    chunk_q = min(chunk_q, s)
    chunk_kv = min(chunk_kv, t)
    if s % chunk_q or t % chunk_kv:
        raise ValueError("seq not divisible by chunks")
    # band for q chunk [qs, qs+cq): kv in (qs - window, qs + cq - 1]
    nb = (window - 1 + chunk_q + chunk_kv - 1) // chunk_kv + 1
    nb = min(nb, t // chunk_kv)
    scale = 1.0 / math.sqrt(d)

    def q_block(blk):
        qb = jax.lax.dynamic_slice_in_dim(q, blk * chunk_q, chunk_q, 1)
        q_start = blk * chunk_q
        lo = q_start - (window - 1) + q_offset   # earliest visible kv pos
        first = jnp.clip(lo // chunk_kv, 0, t // chunk_kv - nb)
        kb = jax.lax.dynamic_slice_in_dim(k, first * chunk_kv,
                                          nb * chunk_kv, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, first * chunk_kv,
                                          nb * chunk_kv, 1)
        q_pos = q_offset + q_start + jnp.arange(chunk_q)
        s_ = jnp.einsum("bqhd,bthd->bhqt", qb, kb,
                        preferred_element_type=jnp.float32) * scale
        s_ = _softcap(s_, softcap)
        k_pos = first * chunk_kv + jnp.arange(nb * chunk_kv)
        m = k_pos[None, :] > q_pos[:, None] - window
        if causal:
            m &= k_pos[None, :] <= q_pos[:, None]
        s_ = s_ + jnp.where(m, 0.0, NEG_INF)[None, None]
        s_ = hint(s_, "batch", "model", None, None)
        p = jax.nn.softmax(s_, axis=-1)
        o = jnp.einsum("bhqt,bthd->bqhd", p.astype(vb.dtype), vb,
                       preferred_element_type=jnp.float32)
        return o

    _, outs = jax.lax.scan(lambda c, i: (c, q_block(i)), None,
                           jnp.arange(s // chunk_q))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)
    return out.astype(q.dtype)


def chunked_attention_cp(q, k, v, *, causal=True, window=0, q_offset=0,
                         chunk_q=512, chunk_kv=1024, softcap: float = 0.0):
    """Context-parallel flash: the Q-CHUNK axis (not heads) carries the
    "model" mesh axis.  Used when n_heads doesn't divide the model axis
    (phi4 24H, hymba 25H, arctic 56H on a 16-way axis): instead of
    replicating attention 16x, each shard owns S/16 of the query rows and
    streams the (small, GQA) KV blocks.  §Perf hillclimb #1."""
    b, s, h, d = q.shape
    t = k.shape[1]
    chunk_q = min(chunk_q, s)
    chunk_kv = min(chunk_kv, t)
    if s % chunk_q or t % chunk_kv:
        raise ValueError("seq not divisible by chunks")
    nc = s // chunk_q
    scale = 1.0 / math.sqrt(d)
    qc = q.reshape(b, nc, chunk_q, h, d)
    qc = hint(qc, "batch", "model", None, None, None)
    n_blocks = t // chunk_kv

    def body(carry, blk):
        acc, m_run, l_run = carry
        kb = jax.lax.dynamic_slice_in_dim(k, blk * chunk_kv, chunk_kv, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, blk * chunk_kv, chunk_kv, 1)
        s_ = jnp.einsum("bnqhd,bthd->bnhqt", qc, kb,
                        preferred_element_type=jnp.float32) * scale
        s_ = _softcap(s_, softcap)
        q_pos = (q_offset + jnp.arange(nc)[:, None] * chunk_q
                 + jnp.arange(chunk_q)[None, :])          # (nc, cq)
        k_pos = blk * chunk_kv + jnp.arange(chunk_kv)
        m = jnp.ones((nc, chunk_q, chunk_kv), bool)
        if causal:
            m &= k_pos[None, None, :] <= q_pos[..., None]
        if window > 0:
            m &= k_pos[None, None, :] > q_pos[..., None] - window
        s_ = s_ + jnp.where(m, 0.0, NEG_INF)[:, None][None]
        s_ = hint(s_, "batch", "model", None, None, None)
        m_new = jnp.maximum(m_run, s_.max(axis=-1))
        p = jnp.exp(s_ - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        pv = jnp.einsum("bnhqt,bthd->bnhqd", p, vb.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, nc, h, chunk_q, d), jnp.float32)
    m0 = jnp.full((b, nc, h, chunk_q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nc, h, chunk_q), jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(body, (acc0, m0, l0),
                                          jnp.arange(n_blocks))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]      # (B,nc,H,cq,D)
    out = jnp.moveaxis(out, 3, 2).reshape(b, s, h, d)
    return out.astype(q.dtype)


def banded_attention_cp(q, k, v, *, window: int, causal=True, q_offset=0,
                        chunk_q=512, chunk_kv=1024, softcap: float = 0.0):
    """Context-parallel sliding window: all q chunks processed as a
    batched (shardable) axis; each chunk gathers its own KV band.  Used
    when heads don't divide the model axis (hymba 25H)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    chunk_q = min(chunk_q, s)
    chunk_kv = min(chunk_kv, t)
    if s % chunk_q or t % chunk_kv:
        raise ValueError("seq not divisible by chunks")
    nc = s // chunk_q
    nb = (window - 1 + chunk_q + chunk_kv - 1) // chunk_kv + 1
    nb = min(nb, t // chunk_kv)
    scale = 1.0 / math.sqrt(d)
    qc = q.reshape(b, nc, chunk_q, h, d)
    qc = hint(qc, "batch", "model", None, None, None)

    q_starts = jnp.arange(nc) * chunk_q
    lo = q_starts - (window - 1) + q_offset
    first = jnp.clip(lo // chunk_kv, 0, t // chunk_kv - nb)   # (nc,)

    def band(fi):
        kb = jax.lax.dynamic_slice_in_dim(k, fi * chunk_kv, nb * chunk_kv, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, fi * chunk_kv, nb * chunk_kv, 1)
        return kb, vb

    kbs, vbs = jax.vmap(band, out_axes=(1, 1))(first)   # (B,nc,nbk,H,D)
    s_ = jnp.einsum("bnqhd,bnthd->bnhqt", qc, kbs,
                    preferred_element_type=jnp.float32) * scale
    s_ = _softcap(s_, softcap)
    q_pos = q_offset + q_starts[:, None] + jnp.arange(chunk_q)[None]
    k_pos = first[:, None] * chunk_kv + jnp.arange(nb * chunk_kv)[None]
    m = k_pos[:, None, :] > q_pos[..., None] - window
    if causal:
        m &= k_pos[:, None, :] <= q_pos[..., None]
    s_ = s_ + jnp.where(m, 0.0, NEG_INF)[None, :, None]
    s_ = hint(s_, "batch", "model", None, None, None)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bnhqt,bnthd->bnqhd", p.astype(vbs.dtype), vbs,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, s, h, d).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, q_offset=0,
              chunk_q=512, chunk_kv=1024, softcap: float = 0.0,
              context_parallel: str = "auto"):
    """Dispatch.  k/v are (B,T,Hkv,D); expanded to full heads here.

    context_parallel: "auto" = shard q chunks over "model" when the head
    count doesn't divide the model axis; "never" | "always" override.
    """
    h = q.shape[2]
    k = repeat_kv(k, h)
    v = repeat_kv(v, h)
    k = hint(k, "batch", None, "model", None)
    v = hint(v, "batch", None, "model", None)
    s, t = q.shape[1], k.shape[1]
    if s * t <= 256 * 256 or s % min(chunk_q, s) or t % min(chunk_kv, t):
        return naive_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, softcap=softcap)
    msize = axis_size("model")
    want_cp = (context_parallel == "always" or
               (context_parallel == "auto" and msize > 1 and h % msize))
    if want_cp:
        # q-chunk count must be a multiple of the model axis: shrink
        # chunk_q if needed (train_4k: 4096/512 = 8 chunks < 16 shards)
        cq = min(chunk_q, s)
        if (s // cq) % msize and s % msize == 0:
            cq = max(s // msize, 1)
        if (s // cq) % msize == 0:
            if window and window < t:
                # banded CP gathers ~(window/chunk_q)x duplicated KV per
                # chunk: only a win when chunk_q >= window (measured:
                # hymba prefill 1.5x win, hymba train 0.8x regression)
                if cq >= window or context_parallel == "always":
                    return banded_attention_cp(
                        q, k, v, window=window, causal=causal,
                        q_offset=q_offset, chunk_q=cq, chunk_kv=chunk_kv,
                        softcap=softcap)
            else:
                return chunked_attention_cp(
                    q, k, v, causal=causal, window=window,
                    q_offset=q_offset, chunk_q=cq, chunk_kv=chunk_kv,
                    softcap=softcap)
    if window and window < t:
        return banded_attention(q, k, v, window=window, causal=causal,
                                q_offset=q_offset, chunk_q=chunk_q,
                                chunk_kv=chunk_kv, softcap=softcap)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, chunk_q=chunk_q,
                             chunk_kv=chunk_kv, softcap=softcap)


# ---------------------------------------------------------------------------
# Decode (single token, ring-buffer KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "pos": jnp.full((cache_len,), -1, jnp.int32),  # absolute pos per slot
    }


def update_kv_cache(cache, k_new, v_new, pos):
    """k_new/v_new (B,1,Hkv,D); pos scalar int32 absolute position."""
    w = cache["k"].shape[1]
    slot = jnp.mod(pos, w)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, 1)
    p = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.asarray(pos, jnp.int32)[None], slot, 0)
    return {"k": k, "v": v, "pos": p}


def decode_attention(q, cache, pos, *, window=0, softcap: float = 0.0):
    """q (B,1,H,D) against ring cache; returns (B,1,H,D).

    Sharding: heads over "model" when divisible; otherwise fall back to
    head_dim sharding (contraction-sharded scores + tiny all-reduce) so
    non-divisible-head archs (arctic 56H, hymba 25H) don't replicate the
    repeated-KV tensor across the model axis.  §Perf hillclimb #2."""
    b, _, h, d = q.shape
    k = repeat_kv(cache["k"], h)
    v = repeat_kv(cache["v"], h)
    msize = axis_size("model")
    if DECODE_HEADDIM_SHARD and msize > 1 and h % msize and d % msize == 0:
        k = hint(k, "batch", None, None, "model")
        v = hint(v, "batch", None, None, "model")
    else:
        k = hint(k, "batch", None, "model", None)
        v = hint(v, "batch", None, "model", None)
    s_ = jnp.einsum("bqhd,bthd->bhqt", q, k,
                    preferred_element_type=jnp.float32) / math.sqrt(d)
    s_ = _softcap(s_, softcap)
    kp = cache["pos"]
    valid = (kp >= 0) & (kp <= pos)
    if window > 0:
        valid &= kp > pos - window
    s_ = s_ + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)
