from repro.optim.optimizer import (
    adam,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    linear_warmup_cosine,
    make_optimizer,
    momentum,
    sgd,
)

__all__ = [
    "make_optimizer", "sgd", "momentum", "adam", "adamw",
    "cosine_schedule", "linear_warmup_cosine",
    "clip_by_global_norm", "global_norm",
]
