from repro.optim.optimizer import (
    make_optimizer,
    sgd,
    momentum,
    adam,
    adamw,
    cosine_schedule,
    linear_warmup_cosine,
    clip_by_global_norm,
    global_norm,
)

__all__ = [
    "make_optimizer", "sgd", "momentum", "adam", "adamw",
    "cosine_schedule", "linear_warmup_cosine",
    "clip_by_global_norm", "global_norm",
]
