"""Minimal pytree optimizer library (no optax dependency).

An optimizer is a pair of pure functions:
    init(params)                      -> state
    update(grads, state, params, lr) -> (updates, state)
Apply with ``apply_updates``.  All moments are f32 regardless of param
dtype (mixed-precision-safe); the FSDP sharding rules in
``repro.sharding`` shard optimizer state like its parameter.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)
                      ).astype(p.dtype), params, updates)


def sgd() -> Optimizer:
    def init(params):
        return ()
    def update(grads, state, params, lr):
        ups = jax.tree_util.tree_map(
            lambda g: -lr * g.astype(jnp.float32), grads)
        return ups, state
    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    def update(grads, state, params, lr):
        new_m = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        ups = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return ups, new_m
    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "t": jnp.zeros((), jnp.int32)}
    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        def upd(m_, v_, p):
            u = -lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u
        ups = jax.tree_util.tree_map(upd, m, v, params)
        return ups, {"m": m, "v": v, "t": t}
    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    return adam(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adam": adam,
            "adamw": adamw}[name](**kw)


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return base_lr * (min_frac + (1 - min_frac)
                          * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)
    def lr(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return lr
