"""fedlint CLI.

    PYTHONPATH=src python -m repro.analysis.fedlint [PATHS...] \\
        [--json OUT] [--select FED001,FED004] [--ignore FED007] \\
        [--list-rules]

Exit codes: 0 = clean (no unwaived findings), 1 = unwaived findings,
2 = usage error (unknown rule code, missing path).  ``--json`` writes
the machine-readable report (schema below) next to the human output;
CI uploads it as an artifact.

JSON schema (``"fedlint": 1``)::

    {"fedlint": 1,
     "paths": [...],                # as given on the command line
     "rules": {"FED001": title, ...},   # the rules that ran
     "findings": [{"file", "line", "col", "rule", "message",
                   "waived", "reason"}, ...],
     "summary": {"files": n, "total": n, "waived": n,
                 "unwaived": n, "by_rule": {"FED003": n, ...}}}
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.core import discover, lint_file
from repro.analysis.rules import RULES
from repro.analysis.waivers import META_RULE


def _parse_codes(spec: str, known: set) -> List[str]:
    codes = [c.strip() for c in spec.split(",") if c.strip()]
    unknown = [c for c in codes if c not in known]
    if unknown:
        raise ValueError(f"unknown rule code(s): {', '.join(unknown)} "
                         f"(known: {', '.join(sorted(known))})")
    return codes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.fedlint",
        description="Repo-invariant static analysis (FED rules).")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to run exclusively")
    ap.add_argument("--ignore", default=None,
                    help="comma-separated rule codes to skip")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    try:
        args = ap.parse_args(argv)
    except SystemExit as e:
        # argparse exits 2 on usage errors already; normalize others
        return 2 if e.code not in (0,) else 0

    known = {r.code for r in RULES}
    if args.list_rules:
        for r in sorted(RULES, key=lambda r: r.code):
            print(f"{r.code}  {r.title}")
            doc = (r.__doc__ or "").strip()
            if doc:
                for ln in doc.splitlines():
                    print(f"    {ln.strip()}")
        return 0

    try:
        selected = list(RULES)
        if args.select:
            codes = set(_parse_codes(args.select, known))
            selected = [r for r in RULES if r.code in codes]
        if args.ignore:
            codes = set(_parse_codes(args.ignore, known))
            selected = [r for r in selected if r.code not in codes]
        files = discover(args.paths)
    except (ValueError, FileNotFoundError) as e:
        print(f"fedlint: error: {e}", file=sys.stderr)
        return 2

    findings = []
    for path, rel in files:
        findings.extend(lint_file(path, rel, selected))

    for f in findings:
        print(f.render())

    waived = sum(1 for f in findings if f.waived)
    unwaived = len(findings) - waived
    by_rule = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    print(f"fedlint: {len(files)} files, {len(findings)} findings "
          f"({waived} waived, {unwaived} unwaived)")

    if args.json:
        report = {
            "fedlint": 1,
            "paths": list(args.paths),
            "rules": {r.code: r.title for r in selected},
            "meta_rule": META_RULE,
            "findings": [f.to_dict() for f in findings],
            "summary": {"files": len(files), "total": len(findings),
                        "waived": waived, "unwaived": unwaived,
                        "by_rule": by_rule},
        }
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1, sort_keys=True)
        print(f"fedlint: report written to {args.json}")

    return 1 if unwaived else 0


if __name__ == "__main__":
    sys.exit(main())
