"""`fedlint`: repo-invariant static analysis.

The FL runtime earned a set of hard correctness contracts that no
generic linter knows about — bit-identical histories across the
store/dict/tiered paths forbid FMA-contractible ``a*b + c`` shapes in
merge/quant code (PR 6/9), the donation contract forbids holding
references into store buffers across a scatter (PR 4), cross-process
determinism died once on a builtin ``hash(str)`` (PR 5), and the
telemetry layer's zero-overhead promise dies the moment a call site
eagerly formats a string (PR 7).  ``repro.analysis`` machine-checks
those invariants over the AST so a future PR cannot silently regress
them:

    PYTHONPATH=src python -m repro.analysis.fedlint src tests benchmarks

Rules are registered in :mod:`repro.analysis.rules` (FED001..FED007),
the waiver syntax (``fedlint: disable=FED00x -- reason`` in a trailing
comment) lives in :mod:`repro.analysis.waivers`, and the driver + CLI
in :mod:`repro.analysis.core` / :mod:`repro.analysis.fedlint`.
"""

from repro.analysis.core import Finding, lint_paths
from repro.analysis.rules import RULES

__all__ = ["Finding", "lint_paths", "RULES"]
