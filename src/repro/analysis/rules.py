"""The FED rule set: each rule codifies a contract a past PR earned
the hard way.  See ROADMAP.md ("Invariant catalogue") for the one-
paragraph history of every rule.

| code   | contract                                                    |
|--------|-------------------------------------------------------------|
| FED001 | donation: no held store-buffer reference used after scatter |
| FED002 | no host syncs in hot paths (engine/state/residency/runtime) |
| FED003 | no FMA-contractible a*b + c in bit-exactness-critical code  |
| FED004 | telemetry call sites stay zero-overhead + catalogued names  |
| FED005 | no per-call / in-loop jax.jit without a compile cache       |
| FED006 | no nondeterminism sources in seeded code paths              |
| FED007 | no bare/broad exception handlers                            |

Rules are deliberately syntactic: they flag the *shape* that bit us,
and the waiver syntax (``fedlint: disable=FED00x -- reason`` in a
trailing comment) is the documented escape hatch for shapes that are
provably benign in context.  False-positive pressure is tuned by each rule's ``applies``
path predicate and small structural exemptions, not by weakening the
pattern.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from repro.analysis.core import (FileContext, Finding, dotted, iter_scopes,
                                 walk_scope)

RULES: List = []


def register(cls):
    RULES.append(cls())
    return cls


def _in(rel: str, *fragments: str) -> bool:
    return any(frag in rel for frag in fragments)


def _finding(ctx: FileContext, node: ast.AST, code: str,
             message: str) -> Finding:
    return Finding(ctx.rel, node.lineno, node.col_offset, code, message,
                   end_line=getattr(node, "end_lineno", None))


# ---------------------------------------------------------------------------
# FED001 — donation contract (PR 4/6)
# ---------------------------------------------------------------------------

@register
class DonationContract:
    """The store owns its buffers: ``scatter``/``merge_scatter``/
    ``write_rows`` run buffer-DONATING jitted programs, so a name bound
    to ``store.buffer``/``store.int_buffer`` before the call aliases
    freed device memory after it.  ``gather`` returns fresh arrays and
    is always safe."""

    code = "FED001"
    title = "store-buffer reference held across a donating scatter"

    _BUF_ATTRS = ("buffer", "int_buffer")
    _SCATTERS = ("scatter", "merge_scatter", "scatter_params",
                 "write_rows")

    def applies(self, rel: str) -> bool:
        return True

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for scope in iter_scopes(ctx.tree):
            yield from self._check_scope(ctx, scope)

    def _check_scope(self, ctx, scope):
        events = []
        for node in walk_scope(scope):
            if (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                tgt = node.targets[0].id
                if (isinstance(node.value, ast.Attribute)
                        and node.value.attr in self._BUF_ATTRS):
                    events.append((node.lineno, node.col_offset, 2,
                                   "bind", tgt, node))
                else:
                    events.append((node.lineno, node.col_offset, 2,
                                   "rebind", tgt, node))
            elif (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in self._SCATTERS):
                events.append((node.lineno, node.col_offset, 1,
                               "scatter", None, node))
            elif isinstance(node, ast.Name) and isinstance(node.ctx,
                                                           ast.Load):
                events.append((node.lineno, node.col_offset, 0,
                               "use", node.id, node))
        held = {}                       # name -> "fresh" | "stale"
        for lineno, col, _prio, kind, name, node in sorted(
                events, key=lambda e: (e[0], e[1], e[2])):
            if kind == "bind":
                held[name] = "fresh"
            elif kind == "rebind":
                held.pop(name, None)
            elif kind == "scatter":
                for k in held:
                    held[k] = "stale"
            elif kind == "use" and held.get(name) == "stale":
                yield _finding(
                    ctx, node, self.code,
                    f"`{name}` was bound to a store buffer before a "
                    "donating scatter/merge_scatter/write_rows call and "
                    "is used after it — the donated buffer is freed "
                    "device memory; re-read the property instead "
                    "(donation contract, PR 4/6)")
                held.pop(name, None)    # one report per held ref


# ---------------------------------------------------------------------------
# FED002 — host sync in hot paths (PR 4/7)
# ---------------------------------------------------------------------------

@register
class HostSyncInHotPath:
    """The server-step hot path must never block the dispatch pipeline:
    ``.item()``, ``np.asarray`` on a device value, ``jax.device_get``
    and ``block_until_ready`` all synchronize the host.  Deliberate
    blocking points (the residency write-behind, the host cold tiers)
    are allow-listed per module below; anything else needs a waiver
    stating why the sync is safe."""

    code = "FED002"
    title = "host synchronization in a hot-path module"

    _HOT = ("core/engine.py", "core/state.py", "core/residency.py",
            "/runtime/")
    # module-scoped allowlist: enclosing function or class names that
    # ARE deliberate host blocking points (documented in ROADMAP).
    _ALLOW = {
        "core/residency.py": {"HostColdTier", "DiskColdTier",
                              "_ensure_hot", "_host_rows",
                              "_scatter_row", "__init__"},
        "core/state.py": {"_ids", "_ef_update", "_ef_block", "__init__"},
    }
    _NP_SYNCS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
    # host-data literals: packing a python list/comprehension is not a
    # device readback, so asarray over them is exempt structurally
    _HOST_ARGS = (ast.List, ast.Tuple, ast.ListComp, ast.GeneratorExp,
                  ast.Constant, ast.Dict)

    def applies(self, rel: str) -> bool:
        return _in(rel, *self._HOT)

    def _allowed(self, ctx: FileContext, node: ast.AST) -> bool:
        allow: Set[str] = set()
        for frag, names in self._ALLOW.items():
            if frag in ctx.rel:
                allow |= names
        if not allow:
            return False
        for fn in ctx.enclosing_functions(node):
            if fn.name in allow:
                return True
        cls = ctx.enclosing_class(node)
        return cls is not None and cls.name in allow

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            msg = self._classify(node)
            if msg and not self._allowed(ctx, node):
                yield _finding(ctx, node, self.code, msg)

    def _classify(self, node: ast.Call) -> Optional[str]:
        name = dotted(node.func)
        if isinstance(node.func, ast.Attribute):
            if node.func.attr == "item" and not node.args:
                return (".item() synchronizes the host on the device "
                        "value — keep the guard on device (lax.cond) or "
                        "waive with the reason the sync is deliberate")
            if node.func.attr == "block_until_ready":
                return ("block_until_ready() stalls the dispatch "
                        "pipeline — hot paths must stay async")
        if name in ("jax.device_get",):
            return ("jax.device_get synchronizes the host — hot paths "
                    "must stay async")
        if name in self._NP_SYNCS:
            if node.args and isinstance(node.args[0], self._HOST_ARGS):
                return None             # packing host data, not a sync
            return (f"{name} on a possibly-device value forces a "
                    "device->host transfer in a hot-path module — if "
                    "the argument is host data or the block is a "
                    "deliberate blocking point, waive with that reason")
        if isinstance(node.func, ast.Name) and node.func.id in ("float",
                                                                "int"):
            if any(isinstance(n, ast.Name) and n.id in ("jnp", "jax",
                                                        "lax")
                   for a in node.args for n in ast.walk(a)):
                return (f"{node.func.id}() on a traced/jax expression "
                        "synchronizes the host in a hot-path module")
        return None


# ---------------------------------------------------------------------------
# FED003 — FMA-contraction hazard (PR 6/9)
# ---------------------------------------------------------------------------

@register
class FmaContractionHazard:
    """XLA CPU contracts ``a*b + c`` into an FMA *differently per
    compilation unit and per shape* (proved experimentally in PR 6:
    (3,P) vs (6,P) merges drift 1 ulp ~30% of trials; PR 9 proved
    ``optimization_barrier`` does NOT stop it).  Bit-exactness-critical
    code must not write the shape at all — restructure as an add
    feeding a mul (the quant path's ``(q + snap) * scale``) or dispatch
    one standalone program for the whole reduction."""

    code = "FED003"
    title = "FMA-contractible a*b + c in bit-exactness-critical code"

    def applies(self, rel: str) -> bool:
        return _in(rel, "/kernels/", "core/state.py")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        state_mode = "core/state.py" in ctx.rel
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, (ast.Add, ast.Sub))):
                continue
            if not self._has_mult_operand(node):
                continue
            if state_mode and not self._traced_context(ctx, node):
                continue                # host int bookkeeping, not math
            yield _finding(
                ctx, node, self.code,
                "a*b + c is FMA-contractible: XLA fuses it differently "
                "per compilation unit, drifting bits across store/dict/"
                "tiered paths — restructure (add feeding a mul, or one "
                "standalone merge program) or waive with the reason "
                "this expression is not bit-identity-gated")

    @staticmethod
    def _has_mult_operand(node: ast.BinOp) -> bool:
        for side in (node.left, node.right):
            if (isinstance(side, ast.BinOp)
                    and isinstance(side.op, ast.Mult)
                    # sequence repetition `(1,) * n` is tuple algebra
                    and not any(isinstance(s, (ast.Tuple, ast.List))
                                for s in (side.left, side.right))):
                return True
        return False

    @staticmethod
    def _traced_context(ctx: FileContext, node: ast.AST) -> bool:
        """In core/state.py only functions that touch jnp/lax are
        traced numerics; byte-count arithmetic over python ints cannot
        drift and stays exempt."""
        fns = ctx.enclosing_functions(node)
        scope = fns[0] if fns else ctx.tree
        return any(isinstance(n, ast.Name) and n.id in ("jnp", "lax")
                   for n in ast.walk(scope))


# ---------------------------------------------------------------------------
# FED004 — telemetry overhead + catalogue drift (PR 7/8)
# ---------------------------------------------------------------------------

@register
class TelemetryOverhead:
    """``obs.TEL`` is a no-op singleton when tracing is off, but python
    evaluates arguments EAGERLY: an f-string, ``.format``/``%`` call,
    or any non-trivial call in the argument list runs on every
    invocation and breaks the zero-overhead contract.  Heavy arguments
    are fine behind an ``enabled`` guard (ancestor ``if tel.enabled:``
    or an early ``if not tel.enabled: return``).  Literal span/metric
    names must come from the documented catalogue
    (``repro.obs.catalogue``) so traces, the validator and
    ``obs.report`` never see an unknown stream."""

    code = "FED004"
    title = "eager work or uncatalogued name at a telemetry call site"

    _METHODS = ("span", "inc", "gauge", "observe")
    _CHEAP_CALLS = {"len", "int", "float", "bool"}

    def applies(self, rel: str) -> bool:
        return True

    # -- handle discovery ----------------------------------------------
    def _handles(self, scope) -> Set[str]:
        """Names that hold the active telemetry in this scope: assigned
        from ``*.TEL``, plus the repo-wide ``tel``/``TEL`` convention."""
        names = {"tel", "TEL"}
        for node in walk_scope(scope):
            if (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                src = dotted(node.value)
                if src is not None and (src == "TEL"
                                        or src.endswith(".TEL")):
                    names.add(node.targets[0].id)
        return names

    def _is_tel_call(self, node: ast.Call, handles: Set[str]) -> bool:
        if not (isinstance(node.func, ast.Attribute)
                and node.func.attr in self._METHODS):
            return False
        recv = dotted(node.func.value)
        if recv is None:
            return False
        return (recv in handles or recv == "TEL"
                or recv.endswith(".TEL"))

    # -- enabled-guard detection ---------------------------------------
    @staticmethod
    def _mentions_enabled(node: ast.AST) -> bool:
        return any(isinstance(n, ast.Attribute) and n.attr == "enabled"
                   for n in ast.walk(node))

    def _guarded(self, ctx: FileContext, node: ast.AST) -> bool:
        for a in ctx.ancestors(node):
            if isinstance(a, ast.If) and self._mentions_enabled(a.test):
                return True
            if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # early `if not tel.enabled: return` above the call
                for stmt in a.body:
                    if (isinstance(stmt, ast.If)
                            and stmt.lineno < node.lineno
                            and self._mentions_enabled(stmt.test)
                            and any(isinstance(s, ast.Return)
                                    for s in stmt.body)):
                        return True
                return False
        return False

    # -- checks ---------------------------------------------------------
    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for scope in iter_scopes(ctx.tree):
            handles = self._handles(scope)
            for node in walk_scope(scope):
                if (isinstance(node, ast.Call)
                        and self._is_tel_call(node, handles)):
                    yield from self._check_call(ctx, node)

    def _check_call(self, ctx, node: ast.Call):
        guarded = self._guarded(ctx, node)
        if not guarded:
            for arg in list(node.args) + [kw.value for kw in
                                          node.keywords]:
                msg = self._eager(arg)
                if msg:
                    yield _finding(
                        ctx, node, self.code,
                        f"{msg} at an unguarded obs.TEL.{node.func.attr} "
                        "call site — arguments evaluate eagerly even "
                        "when tracing is off; guard with `if "
                        "tel.enabled:` or precompute (zero-overhead "
                        "contract, PR 7)")
        # catalogue membership is a production contract: tests and
        # benchmarks may record synthetic names, library code may not
        if ("repro/" in ctx.rel
                and node.args and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            yield from self._check_name(ctx, node,
                                        node.args[0].value)

    def _eager(self, arg: ast.AST) -> Optional[str]:
        for n in ast.walk(arg):
            if isinstance(n, ast.JoinedStr) and any(
                    isinstance(v, ast.FormattedValue) for v in n.values):
                return "eager f-string formatting"
            if (isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod)
                    and isinstance(n.left, ast.Constant)
                    and isinstance(n.left.value, str)):
                return "eager %-formatting"
            if isinstance(n, ast.Call):
                if (isinstance(n.func, ast.Attribute)
                        and n.func.attr == "format"):
                    return "eager .format() call"
                if not (isinstance(n.func, ast.Name)
                        and n.func.id in self._CHEAP_CALLS):
                    callee = dotted(n.func) or "<call>"
                    return f"call-bearing argument ({callee}(...))"
        return None

    def _check_name(self, ctx, node: ast.Call, name: str):
        try:
            from repro.obs import catalogue
        except ImportError:             # pragma: no cover
            return
        kind = node.func.attr
        known = {"span": catalogue.SPANS, "inc": catalogue.COUNTERS,
                 "gauge": catalogue.GAUGES,
                 "observe": catalogue.HISTS}[kind]
        base = name.split("{", 1)[0]
        if base in known:
            return
        if kind == "inc" and base.startswith(catalogue.COUNTER_PREFIXES):
            return
        yield _finding(
            ctx, node, self.code,
            f"{kind} name {name!r} is not in the documented telemetry "
            "catalogue (repro.obs.catalogue) — add it there (and to the "
            "ROADMAP span/counter lists) or fix the typo")


# ---------------------------------------------------------------------------
# FED005 — recompile hazard (PR 1/4)
# ---------------------------------------------------------------------------

@register
class RecompileHazard:
    """``jax.jit`` called per-invocation builds a fresh traced program
    every time: in a loop or an uncached function body it recompiles on
    every call (the store's programs are ``lru_cache``d per layout for
    exactly this reason).  Cache evidence accepted: an enclosing
    ``lru_cache``/``cache`` decorator, ``__init__`` (compile-once-per-
    object), a dict-cache store (`CACHE[key] = ...`), or assignment
    onto ``self``."""

    code = "FED005"
    title = "jax.jit without a compile cache"

    def applies(self, rel: str) -> bool:
        return "repro/" in rel and "/launch/" not in rel

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if name not in ("jax.jit", "jit", "pjit", "jax.pjit"):
                continue
            fns = ctx.enclosing_functions(node)
            in_loop = ctx.in_loop(node) or (
                not fns and any(isinstance(a, (ast.For, ast.While))
                                for a in ctx.ancestors(node)))
            if not fns and not in_loop:
                continue                # module scope compiles once
            if fns and self._cached(ctx, node, fns):
                continue
            where = ("inside a loop" if in_loop
                     else f"in the per-call body of `{fns[0].name}`")
            yield _finding(
                ctx, node, self.code,
                f"{name}(...) {where} builds a fresh program every "
                "call — hoist to module scope, lru_cache the builder, "
                "or store the program in a dict/attribute cache "
                "(recompile hazard)")

    @staticmethod
    def _cached(ctx: FileContext, node: ast.AST, fns) -> bool:
        for fn in fns:
            if fn.name in ("__init__", "__post_init__"):
                return True
            for dec in fn.decorator_list:
                if any(isinstance(n, (ast.Name, ast.Attribute))
                       and getattr(n, "id", getattr(n, "attr", None))
                       in ("lru_cache", "cache")
                       for n in ast.walk(dec)):
                    return True
        # dict-cache idiom anywhere in the outermost enclosing def:
        # the jit result flows into a subscript/self-attribute store
        outer = fns[-1]
        for n in ast.walk(outer):
            if isinstance(n, ast.Assign) and any(
                    isinstance(t, ast.Subscript)
                    or (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self")
                    for t in n.targets):
                return True
        return False


# ---------------------------------------------------------------------------
# FED006 — nondeterminism sources (PR 5)
# ---------------------------------------------------------------------------

@register
class NondeterminismSource:
    """Cross-process byte-identity (gated in test_fl_integration) died
    once on builtin ``hash(str)`` — PYTHONHASHSEED salts it per
    process.  Seeded code paths must not consult process-dependent or
    wall-clock entropy: use ``zlib.crc32``/hashlib for stable salts
    and explicit ``np.random.default_rng``/``PCG64`` streams."""

    code = "FED006"
    title = "nondeterminism source in a seeded code path"

    _NP_DEFAULT = {"seed", "rand", "randn", "randint", "random",
                   "choice", "shuffle", "permutation", "normal",
                   "uniform", "standard_normal", "random_sample",
                   "get_state", "set_state"}
    _PY_RANDOM = {"random", "randint", "randrange", "choice", "choices",
                  "shuffle", "sample", "uniform", "gauss", "seed",
                  "getrandbits"}

    def applies(self, rel: str) -> bool:
        return True

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        in_bench = _in(ctx.rel, "benchmarks/")
        in_timing_ok = in_bench or _in(ctx.rel, "/launch/", "tests/")
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if (isinstance(node.func, ast.Name)
                    and node.func.id == "hash"):
                yield _finding(
                    ctx, node, self.code,
                    "builtin hash() is PYTHONHASHSEED-salted per "
                    "process (PR 5's cross-process bug) — use "
                    "zlib.crc32 or hashlib for a stable salt")
            elif name == "time.time" and not in_timing_ok:
                yield _finding(
                    ctx, node, self.code,
                    "time.time() in a seeded code path — simulated "
                    "time must come from the EventQueue virtual clock; "
                    "host timing belongs in benchmarks/launch "
                    "(perf_counter)")
            elif name is not None and self._np_default(name):
                yield _finding(
                    ctx, node, self.code,
                    f"{name}() uses numpy's process-global default RNG "
                    "— thread an explicit np.random.default_rng(seed) "
                    "stream instead")
            elif (name is not None and name.startswith("random.")
                    and name.split(".")[1] in self._PY_RANDOM):
                yield _finding(
                    ctx, node, self.code,
                    f"{name}() uses the stdlib global RNG — thread an "
                    "explicit seeded generator instead")
            elif (name is not None and not in_bench
                    and (name.endswith("datetime.now")
                         or name.endswith("datetime.utcnow")
                         or name.endswith("datetime.today")
                         or name.endswith("date.today"))):
                yield _finding(
                    ctx, node, self.code,
                    f"{name}() reads civil time in a seeded code path "
                    "— timestamps belong in benchmarks or run metadata")

    def _np_default(self, name: str) -> bool:
        parts = name.split(".")
        return (len(parts) == 3 and parts[0] in ("np", "numpy")
                and parts[1] == "random"
                and parts[2] in self._NP_DEFAULT)


# ---------------------------------------------------------------------------
# FED007 — bare/broad exception handlers
# ---------------------------------------------------------------------------

@register
class BroadExcept:
    """A bare ``except:`` or ``except Exception:`` swallows
    KeyboardInterrupt-adjacent failures and — worse here — XLA/jax
    errors that signal a numerics contract break.  Narrow the type, or
    waive with the reason the broad catch is load-bearing (e.g. a
    sweep harness that records per-item failures and continues)."""

    code = "FED007"
    title = "bare or broad exception handler"

    _BROAD = ("Exception", "BaseException")

    def applies(self, rel: str) -> bool:
        return True

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield _finding(ctx, node, self.code,
                               "bare `except:` — name the exception "
                               "types this handler is meant to catch")
                continue
            broad = [dotted(t) for t in
                     (node.type.elts if isinstance(node.type, ast.Tuple)
                      else [node.type])]
            hit = [b for b in broad if b in self._BROAD]
            if hit:
                yield _finding(
                    ctx, node, self.code,
                    f"`except {hit[0]}` is too broad — narrow to the "
                    "failure types this site expects, or waive with "
                    "the reason the catch-all is deliberate")
