"""Per-line waiver syntax for fedlint findings.

A finding is waived by a trailing ``fedlint: disable=FED00x -- reason``
comment on any physical line of the flagged statement (see the
ROADMAP's invariant-catalogue section for a literal example; spelling
one out here would waive *this* file).

* one or more rule codes, comma-separated: ``disable=FED002,FED006``
* the reason after `` -- `` is REQUIRED — a waiver without one is
  itself a finding (FED000), as is a waiver that names an unknown rule
  or never matches a finding.  Waivers are an audit trail, not an
  off-switch.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

#: the meta-rule code for waiver-syntax problems (bad code, missing
#: reason, waiver that matched nothing) and unparseable files.
META_RULE = "FED000"

_WAIVER_RE = re.compile(r"#\s*fedlint:\s*disable=([^#]*?)(?:--(.*))?$")
_CODE_RE = re.compile(r"^FED\d{3}$")


@dataclass
class Waiver:
    line: int                       # 1-indexed line the comment sits on
    codes: Tuple[str, ...]
    reason: str
    used: bool = False
    problems: List[str] = field(default_factory=list)

    @property
    def valid(self) -> bool:
        return not self.problems


def parse_waivers(lines: List[str]) -> Dict[int, Waiver]:
    """Scan source lines for waiver comments.  Returns ``{line: Waiver}``;
    malformed waivers are returned too, carrying their ``problems`` so
    the driver can report them under FED000."""
    out: Dict[int, Waiver] = {}
    for i, line in enumerate(lines, start=1):
        m = _WAIVER_RE.search(line)
        if m is None:
            continue
        raw_codes, raw_reason = m.group(1), m.group(2)
        codes = tuple(c.strip() for c in raw_codes.split(",") if c.strip())
        reason = (raw_reason or "").strip()
        problems = []
        if not codes:
            problems.append("waiver names no rule codes")
        for c in codes:
            if not _CODE_RE.match(c):
                problems.append(f"malformed rule code {c!r} "
                                "(expected FED###)")
        if not reason:
            problems.append("waiver is missing its required reason "
                            "(`fedlint: disable=FED00x -- why`)")
        out[i] = Waiver(line=i, codes=codes, reason=reason,
                        problems=problems)
    return out
