"""fedlint driver: file discovery, rule dispatch, waiver application.

The unit of work is one Python file: parse it once, hand the
:class:`FileContext` (AST + parent map + source lines) to every rule
whose ``applies(relpath)`` predicate matches, then resolve the raw
findings against the file's waiver comments.  A finding is *waived*
when a valid waiver naming its rule code sits on any physical line of
the flagged statement; waived findings stay in the report (with their
reason) but do not fail the run.  Waivers that match nothing, name
unknown codes, or omit the required reason are themselves findings
under the FED000 meta-rule.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.waivers import META_RULE, Waiver, parse_waivers


@dataclass
class Finding:
    file: str
    line: int
    col: int
    rule: str
    message: str
    waived: bool = False
    reason: Optional[str] = None
    end_line: Optional[int] = None

    def to_dict(self) -> Dict:
        return {"file": self.file, "line": self.line, "col": self.col,
                "rule": self.rule, "message": self.message,
                "waived": self.waived, "reason": self.reason}

    def render(self) -> str:
        tag = f" [waived: {self.reason}]" if self.waived else ""
        return (f"{self.file}:{self.line}:{self.col}: "
                f"{self.rule} {self.message}{tag}")


class FileContext:
    """Everything a rule needs about one parsed file."""

    def __init__(self, path: str, rel: str, source: str, tree: ast.AST):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node

    # -- navigation helpers (shared by the rules) -----------------------
    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            yield cur
            cur = self.parents.get(cur)

    def enclosing_functions(self, node: ast.AST) -> List[ast.AST]:
        """Innermost-first chain of enclosing function defs."""
        return [a for a in self.ancestors(node)
                if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef))]

    def enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        for a in self.ancestors(node):
            if isinstance(a, ast.ClassDef):
                return a
        return None

    def in_loop(self, node: ast.AST) -> bool:
        """Inside a for/while body, stopping at function boundaries
        (a loop *outside* the enclosing def does not re-run its body)."""
        for a in self.ancestors(node):
            if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                return False
            if isinstance(a, (ast.For, ast.AsyncFor, ast.While)):
                return True
        return False


_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def iter_scopes(tree: ast.AST) -> Iterable[ast.AST]:
    """Module + every function def (each is one lint scope)."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, _SCOPE_NODES):
            yield node


def walk_scope(scope: ast.AST) -> Iterable[ast.AST]:
    """Walk one scope WITHOUT descending into nested function scopes
    (their bindings are their own scope's business).  Class bodies
    execute in the enclosing scope and are descended into."""
    stack = [scope.body] if isinstance(scope, ast.Lambda) \
        else list(scope.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, _SCOPE_NODES):
            continue                    # nested scope: don't descend
        for child in ast.iter_child_nodes(node):
            stack.append(child)


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# -- per-file lint --------------------------------------------------------

def lint_file(path: str, rel: str, rules: Sequence) -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rel, e.lineno or 1, e.offset or 0, META_RULE,
                        f"syntax error: {e.msg}")]
    ctx = FileContext(path, rel, source, tree)
    findings: List[Finding] = []
    for rule in rules:
        if rule.applies(ctx.rel):
            findings.extend(rule.check(ctx))
    waivers = parse_waivers(ctx.lines)
    findings = _apply_waivers(ctx.rel, findings, waivers,
                              active={r.code for r in rules})
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


def _apply_waivers(rel: str, findings: List[Finding],
                   waivers: Dict[int, Waiver],
                   active: set) -> List[Finding]:
    out: List[Finding] = []
    for f in findings:
        span = range(f.line, (f.end_line or f.line) + 1)
        for ln in span:
            w = waivers.get(ln)
            if w is not None and w.valid and f.rule in w.codes:
                w.used = True
                f.waived = True
                f.reason = w.reason
                break
        out.append(f)
    for w in waivers.values():
        for problem in w.problems:
            out.append(Finding(rel, w.line, 0, META_RULE, problem))
        # an unused waiver is dead weight that hides nothing today and
        # could hide a regression tomorrow — but only call it unused
        # when every rule it names actually ran this invocation.
        if w.valid and not w.used and all(c in active for c in w.codes):
            out.append(Finding(
                rel, w.line, 0, META_RULE,
                f"unused waiver for {','.join(w.codes)}: no matching "
                "finding on this line"))
    return out


# -- path discovery -------------------------------------------------------

def discover(paths: Sequence[str]) -> List[Tuple[str, str]]:
    """Expand files/directories into ``(abspath, display_path)`` pairs.
    Raises ``FileNotFoundError`` for a missing input path."""
    out: List[Tuple[str, str]] = []
    for p in paths:
        if os.path.isfile(p):
            out.append((os.path.abspath(p), p))
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if not d.startswith(".")
                                 and d != "__pycache__")
                for name in sorted(files):
                    if name.endswith(".py"):
                        full = os.path.join(root, name)
                        out.append((os.path.abspath(full), full))
        else:
            raise FileNotFoundError(p)
    return out


def lint_paths(paths: Sequence[str], rules: Sequence) -> List[Finding]:
    findings: List[Finding] = []
    for path, rel in discover(paths):
        findings.extend(lint_file(path, rel, rules))
    return findings
