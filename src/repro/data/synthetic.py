"""Deterministic synthetic datasets.

This container is offline, so MNIST / Fashion-MNIST / CIFAR-10 are
replaced by synthetic datasets of *identical shape and cardinality*.
Each class is a smooth random prototype (mixture of 2-D Gabor-like
gratings) plus per-sample warp + noise — linearly non-separable but
CNN-learnable, so accuracy curves behave qualitatively like the real
datasets.  All FL methods see identical data, so the paper's *relative*
claims (FedDCT vs baselines) are preserved (DESIGN.md §2).
"""

from __future__ import annotations

import zlib
from typing import Dict

import numpy as np


def _name_salt(name: str) -> int:
    """Stable per-dataset seed offset.  Python's builtin ``hash(str)``
    is salted per process (PYTHONHASHSEED), which made every new
    process generate DIFFERENT "mnist" pixels for the same seed — the
    source of the cross-process run-to-run nondeterminism in
    ``fl_train.py``.  crc32 is a pure function of the bytes, so two
    processes (and two machines) now agree."""
    return zlib.crc32(name.encode("utf-8")) % (2 ** 16)


_SPECS = {
    "mnist": dict(hw=(28, 28, 1), n_classes=10, n_train=60_000, n_test=10_000),
    "fmnist": dict(hw=(28, 28, 1), n_classes=10, n_train=60_000, n_test=10_000),
    "cifar10": dict(hw=(32, 32, 3), n_classes=10, n_train=50_000, n_test=10_000),
}


def _prototypes(rng, hw, n_classes, n_gratings=6):
    h, w, c = hw
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    protos = np.zeros((n_classes, h, w, c), np.float32)
    for k in range(n_classes):
        for _ in range(n_gratings):
            fx, fy = rng.uniform(0.05, 0.5, 2)
            ph = rng.uniform(0, 2 * np.pi)
            amp = rng.uniform(0.4, 1.0)
            cx, cy = rng.uniform(0.2, 0.8, 2) * np.array([w, h])
            env = np.exp(-(((xx - cx) / (0.4 * w)) ** 2
                           + ((yy - cy) / (0.4 * h)) ** 2))
            g = amp * env * np.sin(2 * np.pi * (fx * xx + fy * yy) + ph)
            for ch in range(c):
                protos[k, :, :, ch] += g * rng.uniform(0.5, 1.0)
    protos /= np.abs(protos).max(axis=(1, 2, 3), keepdims=True) + 1e-6
    return protos


def make_image_dataset(name: str, seed: int = 0, scale: float = 1.0
                       ) -> Dict[str, np.ndarray]:
    """Returns {x_train, y_train, x_test, y_test}.  ``scale`` shrinks the
    dataset cardinality for fast CI runs (1.0 = paper-sized)."""
    spec = _SPECS[name]
    rng = np.random.default_rng(seed + _name_salt(name))
    hw, ncls = spec["hw"], spec["n_classes"]
    n_train = int(spec["n_train"] * scale)
    n_test = int(spec["n_test"] * scale)
    protos = _prototypes(rng, hw, ncls)

    def gen(n):
        y = rng.integers(0, ncls, n).astype(np.int32)
        x = protos[y]
        # per-sample global shift (cheap warp) + noise
        sx = rng.integers(-2, 3, n)
        sy = rng.integers(-2, 3, n)
        x = np.stack([np.roll(np.roll(img, a, 0), b, 1)
                      for img, a, b in zip(x, sx, sy)])
        x = x * rng.uniform(0.7, 1.3, (n, 1, 1, 1)).astype(np.float32)
        x = x + rng.normal(0, 0.35, x.shape).astype(np.float32)
        return x.astype(np.float32), y

    x_tr, y_tr = gen(n_train)
    x_te, y_te = gen(n_test)
    return {"x_train": x_tr, "y_train": y_tr, "x_test": x_te, "y_test": y_te,
            "n_classes": ncls, "hw": hw}


def make_token_dataset(vocab_size: int, n_tokens: int, seed: int = 0,
                       order: int = 2) -> np.ndarray:
    """Synthetic LM corpus: sparse high-order Markov chain over a Zipf
    vocabulary — has real sequential structure so LM losses decrease."""
    rng = np.random.default_rng(seed)
    v = int(vocab_size)
    zipf = 1.0 / np.arange(1, v + 1) ** 1.1
    zipf /= zipf.sum()
    # each context hash maps to a small candidate set
    n_ctx = 4096
    cand = rng.integers(0, v, (n_ctx, 8))
    toks = np.empty(n_tokens, np.int64)
    toks[:order] = rng.integers(0, v, order)
    state = 0
    for i in range(order, n_tokens):
        state = (state * 31 + int(toks[i - 1])) % n_ctx
        if rng.random() < 0.75:
            toks[i] = cand[state, rng.integers(0, 8)]
        else:
            toks[i] = rng.choice(v, p=zipf)
    return toks.astype(np.int32)
