from repro.data.partition import (
    dirichlet_partition,
    iid_partition,
    primary_class_partition,
)
from repro.data.pipeline import ClientDataset, client_batches
from repro.data.synthetic import make_image_dataset, make_token_dataset

__all__ = [
    "make_image_dataset", "make_token_dataset",
    "primary_class_partition", "dirichlet_partition", "iid_partition",
    "ClientDataset", "client_batches",
]
