"""Per-client batching pipeline (deterministic, seed-keyed)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass
class ClientDataset:
    x: np.ndarray
    y: np.ndarray

    def __len__(self):
        return len(self.y)


def client_batches(ds: ClientDataset, batch_size: int, epoch_seed: int
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """One local epoch of shuffled batches (drops ragged tail like FedLab)."""
    rng = np.random.default_rng(epoch_seed)
    idx = rng.permutation(len(ds))
    n_full = max(len(ds) // batch_size, 1)
    for b in range(n_full):
        sl = idx[b * batch_size:(b + 1) * batch_size]
        if len(sl) == 0:
            break
        yield ds.x[sl], ds.y[sl]


def lm_batches(tokens: np.ndarray, batch: int, seq: int, seed: int
               ) -> Iterator[np.ndarray]:
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, batch)
        yield np.stack([tokens[s:s + seq] for s in starts])
