"""Non-iid client partitioners (paper §5.1: primary-class fraction "#")."""

from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(labels: np.ndarray, n_clients: int, seed: int = 0
                  ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(labels))
    return [np.sort(s) for s in np.array_split(idx, n_clients)]


def primary_class_partition(labels: np.ndarray, n_clients: int,
                            primary_frac: float, seed: int = 0
                            ) -> List[np.ndarray]:
    """Paper's scheme: each client gets a random primary class holding
    ``primary_frac`` of its samples; the rest is drawn uniformly from the
    other classes.  primary_frac<=1/n_classes degenerates to iid."""
    n_classes = int(labels.max()) + 1
    if primary_frac <= 1.0 / n_classes:
        return iid_partition(labels, n_clients, seed)
    rng = np.random.default_rng(seed)
    by_class = [rng.permutation(np.where(labels == c)[0]).tolist()
                for c in range(n_classes)]
    per_client = len(labels) // n_clients
    n_primary = int(round(primary_frac * per_client))
    primaries = rng.integers(0, n_classes, n_clients)
    out: List[np.ndarray] = []
    for ci in range(n_clients):
        pc = int(primaries[ci])
        take: List[int] = []
        pool = by_class[pc]
        k = min(n_primary, len(pool))
        take += pool[:k]
        by_class[pc] = pool[k:]
        # fill the remainder from other classes (round-robin by size)
        need = per_client - len(take)
        others = [c for c in range(n_classes) if c != pc]
        while need > 0:
            sizes = np.array([len(by_class[c]) for c in others])
            if sizes.sum() == 0:
                break
            c = others[int(np.argmax(sizes))]
            take.append(by_class[c].pop())
            need -= 1
        out.append(np.array(sorted(take), np.int64))
    return out


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0) -> List[np.ndarray]:
    """Standard Dirichlet(alpha) label-skew partition (extra, beyond paper)."""
    n_classes = int(labels.max()) + 1
    rng = np.random.default_rng(seed)
    out = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = rng.permutation(np.where(labels == c)[0])
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for ci, part in enumerate(np.split(idx, cuts)):
            out[ci] += part.tolist()
    return [np.array(sorted(s), np.int64) for s in out]
