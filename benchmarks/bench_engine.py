"""A/B benchmark: batched multi-client engine vs seed per-client loop,
and Pallas fedagg kernel vs reference aggregation.

    PYTHONPATH=src python benchmarks/bench_engine.py [--rounds 8]
        [--clients 20] [--cohort 16] [--config small|paper|both]

Measures the per-round *server step* (local training of the cohort +
on-device aggregation) with a warm jit cache — virtual/wireless time is
irrelevant here, this is real wall-clock.  Equivalence of the two
engines' aggregated parameters is asserted before timing, so the
speedup is apples-to-apples.

The "small" config is the paper's FL regime (tiny CNN, many clients,
batch 10) where the per-client Python loop is dispatch-bound and the
batched engine wins big; "paper" is the full-size cnn-mnist model,
which on a small CPU is compute-saturated (speedup ~1x there; the
batched path is the one that scales on real accelerators).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import add_json_arg, maybe_write_json
from repro.config import get_arch
from repro.config.base import FLConfig
from repro.core.aggregation import weighted_average_stacked
from repro.core.engine import make_engine
from repro.fl.client import CNNTrainer


def _block(tree):
    jax.block_until_ready(jax.tree_util.tree_leaves(tree))


def bench_round(trainer, cohort, rounds: int):
    """Warm both engines, assert parity, then time train_round."""
    params = trainer.init_params(0)
    engines = {"batched": make_engine(trainer, engine="batched"),
               "looped": make_engine(trainer, engine="looped")}
    warm = {}
    for name, eng in engines.items():
        warm[name] = eng.train_round(params, cohort, 1)
        _block(warm[name])
    for a, b in zip(jax.tree_util.tree_leaves(warm["batched"]),
                    jax.tree_util.tree_leaves(warm["looped"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
    out = {}
    for name, eng in engines.items():
        t0 = time.perf_counter()
        for r in range(2, 2 + rounds):
            _block(eng.train_round(params, cohort, r))
        out[name] = (time.perf_counter() - t0) / rounds
    return out


def bench_agg(n_clients: int = 32, p: int = 1 << 20, iters: int = 20):
    """Stacked-buffer aggregation: fused kernel vs jnp reference."""
    rng = np.random.default_rng(0)
    stacked = {"w": jnp.asarray(
        rng.normal(size=(n_clients, p // 2)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n_clients, p // 2)).astype(
            np.float32))}
    w = jnp.asarray(rng.uniform(0.5, 2.0, n_clients).astype(np.float32))
    out = {}
    for name, use_kernel in (("kernel", True), ("reference", False)):
        _block(weighted_average_stacked(stacked, w, use_kernel=use_kernel))
        t0 = time.perf_counter()
        for _ in range(iters):
            _block(weighted_average_stacked(stacked, w,
                                            use_kernel=use_kernel))
        out[name] = (time.perf_counter() - t0) / iters
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--cohort", type=int, default=16)
    ap.add_argument("--config", default="small",
                    choices=["small", "paper", "both"])
    ap.add_argument("--agg-p", type=int, default=1 << 20)
    ap.add_argument("--out", default=None)
    add_json_arg(ap, "engine")
    args = ap.parse_args(argv)

    results = {}
    configs = ["small", "paper"] if args.config == "both" else [args.config]
    for which in configs:
        cfg = get_arch("cnn-mnist")
        if which == "small":
            cfg = cfg.reduced()
        fl = FLConfig(n_clients=args.clients, n_tiers=4, tau=4, rounds=3,
                      mu=0.0, primary_frac=0.7, seed=0, lr=0.003)
        trainer = CNNTrainer(cfg, fl, "mnist", scale=0.01)
        cohort = list(range(min(args.cohort, args.clients)))
        times = bench_round(trainer, cohort, args.rounds)
        speedup = times["looped"] / times["batched"]
        results[which] = {"batched_s": times["batched"],
                          "looped_s": times["looped"],
                          "speedup": speedup,
                          "cohort": len(cohort)}
        print(f"[{which:5s}] cohort={len(cohort):3d} "
              f"batched={times['batched']*1e3:8.1f} ms/round  "
              f"looped={times['looped']*1e3:8.1f} ms/round  "
              f"speedup={speedup:5.2f}x")

    agg = bench_agg(p=args.agg_p)
    results["aggregation"] = {"kernel_s": agg["kernel"],
                              "reference_s": agg["reference"]}
    print(f"[agg  ] P={args.agg_p} kernel={agg['kernel']*1e3:8.1f} ms  "
          f"reference={agg['reference']*1e3:8.1f} ms")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"[bench_engine] results -> {args.out}")
    maybe_write_json(args, "engine", results,
                     extra_context={"configs": configs,
                                    "rounds": args.rounds,
                                    "agg_p": args.agg_p})
    return results


if __name__ == "__main__":
    main()
