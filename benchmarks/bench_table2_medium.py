"""Medium-scale Table 2 (closer to paper dynamics than --ci, feasible on
1 CPU): 50 clients, 150 rounds, tau=5, mu=0.1, #=0.7.  The cross-tier
selection effect needs >~50 rounds to surface (the tier pointer has to
climb, Fig. 9), which the CI-scale run is too short for."""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import (RESULTS_DIR, add_json_arg, maybe_write_json,
                               run_fl_experiment)

METHODS = ["fedavg", "tifl", "fedasync", "feddct"]
SETTINGS = dict(rounds=150, n_clients=50, tau=5, scale=0.05, eval_every=2,
                mu=0.1, primary_frac=0.7)
TARGETS = {"cnn-mnist": 0.60, "cnn-fmnist": 0.45}


def run(workloads=("cnn-mnist", "cnn-fmnist"), args=None):
    rows = []
    for arch in workloads:
        for method in METHODS:
            h = run_fl_experiment(arch=arch, method=method,
                                  tag=f"medium_{method}_{arch}", **SETTINGS)
            tt = h.time_to_accuracy(TARGETS[arch])
            rows.append({"dataset": arch, "method": method,
                         "best_acc": round(h.best_accuracy(smooth=3), 4),
                         "time_to_target_s": round(tt, 1) if tt else None,
                         "target": TARGETS[arch],
                         "total_time_s": round(h.times[-1], 1)})
            print(f"[table2-med] {arch:12s} {method:9s} "
                  f"acc={rows[-1]['best_acc']:.4f} "
                  f"t@{TARGETS[arch]}={rows[-1]['time_to_target_s']} "
                  f"total={rows[-1]['total_time_s']}", flush=True)
    with open(os.path.join(RESULTS_DIR, "table2_medium.json"), "w") as f:
        json.dump(rows, f, indent=1)
    if args is not None:
        maybe_write_json(args, "table2_medium", {"rows": rows},
                         extra_context={"settings": SETTINGS,
                                        "targets": TARGETS})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    add_json_arg(ap, "table2_medium")
    return run(args=ap.parse_args(argv))


if __name__ == "__main__":
    main()
