"""Beyond-paper ablations of FedDCT's own hyper-parameters:
timeout tolerance beta, evaluation rounds kappa, tier count M, and the
Dirichlet partitioner (alternative non-iid model).

    PYTHONPATH=src python -m benchmarks.bench_ablations [--json [PATH]]
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import RESULTS_DIR, add_json_arg, maybe_write_json
from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.network import WirelessNetwork

S = dict(n_clients=20, tau=3, rounds=25, mu=0.2, primary_frac=0.7, seed=0,
         lr=0.003)


def _run(tag, **kw):
    cfg = dict(S)
    cfg.update(kw)
    fl = FLConfig(**cfg)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    tr = build_fl_clients("cnn-mnist", fl, scale=0.02)
    h = run_method("feddct", tr, net, fl, eval_every=5)
    rec = {"tag": tag, "best_acc": h.best_accuracy(smooth=1),
           "total_time": h.times[-1],
           "stragglers": sum(h.n_stragglers)}
    print(f"[ablate] {tag:18s} acc={rec['best_acc']:.4f} "
          f"T={rec['total_time']:7.1f}s stragglers={rec['stragglers']}",
          flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    add_json_arg(ap, "ablations")
    args = ap.parse_args(argv)

    out = []
    for beta in (1.0, 1.2, 1.5, 2.0):
        out.append(_run(f"beta={beta}", beta=beta))
    for kappa in (1, 2, 3):
        out.append(_run(f"kappa={kappa}", kappa=kappa))
    for m in (2, 5, 10):
        out.append(_run(f"M={m}", n_tiers=m))
    for omega in (15.0, 30.0, 60.0):
        out.append(_run(f"omega={omega}", omega=omega))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "ablations.json"), "w") as f:
        json.dump(out, f, indent=1)
    maybe_write_json(args, "ablations", {"cells": out},
                     extra_context={"setting": S})
    return out


if __name__ == "__main__":
    main()
