"""A/B benchmark: device-resident ClientStateStore vs dict-of-pytrees.

    PYTHONPATH=src python benchmarks/bench_store.py [--clients 32]
        [--tau 8] [--rounds 16] [--window 16] [--reps 5]
        [--smoke] [--json [PATH]]

Both arms run the SAME event-driven windowed async runtime
(``AsyncRunner``) over the same ``WirelessNetwork`` realization and
update budget; the only difference is where client snapshots live:

* dict  — ``use_store=False``: a ``Dict[int, pytree]`` of N scattered
  model copies, re-stacked leaf by leaf (``tree_map(jnp.stack)``) on
  every drained window (the PR 2 behaviour);
* store — ``use_store=True``: one flat (N, P) device buffer, gathered
  per window and re-scattered by the donating store programs
  (``engine.train_window``);
* tiered — ``store_capacity=8`` < clients: the hot/cold residency
  store (``TieredClientStateStore``) with only 8 rows on device, so
  every window promotes misses and evicts dirty LRU victims to host;
* quant8 — ``quant_bits=8``: int8 quantized rows with per-leaf fused
  scales and server-side error feedback.  This arm's history is NOT
  bit-identical to the f32 arms (gated convergence delta by design);
  the smoke gate instead asserts the claimed row-format contract —
  ``meta["quant_bits"] == 8`` and >= 3.5x lower resident store bytes
  than the dense f32 arm — plus its events/sec lands in the JSON so
  ``compare.py`` bands the quantize/dequantize overhead over time.

A non-smoke run also reports the population-scale residency
microbench (``--residency-rows``, default 100k logical clients over a
512-row hot tier): rows/sec through the gather/re-snapshot cycle plus
promote/demote counters.  The cold tier is sparse, so N=100k fits a
2-core CPU box.

Histories are bit-identical by construction (asserted every run), so
the harness measures pure server-step overhead: merged client updates
per second over the whole run, plus a snapshot-assembly micro-bench at
cohort 16 ("peak stacking": ``tree_map(jnp.stack)`` over 16 snapshot
pytrees vs one ``store.gather``).

The trainer is a synthetic many-leaf model (24 leaves, ~6k params)
whose cohort step is a single jitted elementwise update: local
training is deliberately cheap so the number isolates the snapshot
gather/stack + merge + re-snapshot path the store replaces.  Real
models shift both arms by the same training time, so the store's win
is a lower bound on nothing and an upper bound on everything — read it
as "server-step overhead shrinks by this factor", not end-to-end
wall-clock.

``--smoke`` is the CI-sized run (< 30 s on 2 CPU cores): exits
non-zero unless windows actually batch (mean cohort > 1), histories
match bit-for-bit, and the store arm beats dict events/sec.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from common import (add_json_arg, maybe_write_json, time_fn, timed_reps,
                    traced_run)
from repro.config.base import FLConfig
from repro.core.state import ClientStateStore
from repro.fl.network import WirelessNetwork
from repro.fl.testing import SyntheticCohortTrainer
from repro.runtime.async_loop import AsyncRunner


def ManyLeafTrainer():
    """24-leaf synthetic model (shared trainer-contract implementation
    in ``repro.fl.testing``): enough uniform leaves that leaf-by-leaf
    snapshot stacking dominates the dict arm's server step."""
    return SyntheticCohortTrainer.many_leaf(n_leaves=24, leaf=256)


def run_arm(trainer, fl, seed, *, use_store: bool, window: int,
            reps: int, store_capacity=None, quant_bits=32):
    """``reps`` timed runs over identical realizations (the shared
    trainer keeps both arms' jit caches warm after the warmup pass, so
    reps measure steady-state server overhead); best-rep summary +
    median-of-reps gate statistic via ``common.timed_reps``.
    ``store_capacity`` < n_clients selects the tiered hot/cold store
    (histories stay bit-identical; the arm measures residency cost);
    ``quant_bits=8`` selects int8 quantized rows + error feedback."""
    hists = []

    def once():
        net = WirelessNetwork(fl.n_clients, fl.tier_delay_means,
                              fl.delay_std, fl.mu, fl.failure_delay, seed)
        runner = AsyncRunner(trainer, net, fl, window=window,
                             eval_every=fl.rounds * fl.tau + 1,
                             use_store=use_store,
                             store_capacity=store_capacity,
                             quant_bits=quant_bits)
        t0 = time.perf_counter()
        hist = runner.run()
        wall = time.perf_counter() - t0
        hists.append(hist)
        return wall, sum(runner.cohort_sizes), {
            "mean_cohort": hist.meta["mean_cohort"],
            "n_drains": hist.meta["n_drains"],
            "residency": hist.meta["residency"],
            "hot_rows": hist.meta["hot_rows"]}

    out = timed_reps(once, reps)
    # phase-time breakdown (gather/train/merge/scatter/eviction) from
    # ONE extra traced rep; timed reps stay untraced.  All reps are
    # bit-identical, so the extra history appended to ``hists`` is
    # indistinguishable from the timed ones.
    out["phases"] = traced_run(once)
    return out, hists[-1]


def stacking_microbench(cohort: int):
    """Median microseconds to assemble a cohort's start snapshots:
    leaf-by-leaf stacking of ``cohort`` pytrees vs one store gather."""
    trainer = ManyLeafTrainer()
    params = trainer.init_params(0)
    snapshots = [trainer.init_params(i) for i in range(cohort)]

    def stack_arm():
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                      *snapshots)

    store = ClientStateStore(params, cohort)
    for i, s in enumerate(snapshots):
        store.scatter_params([i], s)
    ids = list(range(cohort))

    def gather_arm():
        return store.gather(ids)

    return {"stack_us": time_fn(stack_arm, iters=30),
            "store_gather_us": time_fn(gather_arm, iters=30)}


def residency_microbench(n_rows: int, *, capacity: int = 512,
                         cohort: int = 16, windows: int = 64,
                         seed: int = 0, quant_bits: int = 32):
    """Population-scale tiered store: ``n_rows`` logical clients with
    only ``capacity`` rows resident on device and the rest in the
    sparse host cold tier (untouched clients cost nothing — the tier
    materializes a row on first write, so N=100k fits a 2-core CPU
    box).  Each window gathers a random cohort (promoting misses,
    evicting dirty LRU victims write-behind) and re-snapshots it, the
    same hot-path cycle ``AsyncRunner`` drives.  Reports rows/sec
    through the residency layer plus the promote/demote counters.
    ``quant_bits=8`` stores int8 rows in both tiers, so every demoted
    cold row is ~4x smaller (reported as ``cold_row_bytes``)."""
    import numpy as np
    from repro.core.residency import TieredClientStateStore
    trainer = ManyLeafTrainer()
    params = trainer.init_params(0)
    store = TieredClientStateStore(params, n_rows, capacity=capacity,
                                   quant_bits=quant_bits)
    rng = np.random.default_rng(seed)
    picks = [sorted(rng.choice(n_rows, size=cohort, replace=False).tolist())
             for _ in range(windows)]
    # warm the per-cohort-width jit bucket off the clock
    store.ensure_window(picks[0])
    jax.block_until_ready(jax.tree_util.tree_leaves(store.gather(picks[0])))
    store.scatter_params(picks[0], params)
    t0 = time.perf_counter()
    for ids in picks:
        store.ensure_window(ids)
        jax.block_until_ready(jax.tree_util.tree_leaves(store.gather(ids)))
        store.scatter_params(ids, params)
    wall = time.perf_counter() - t0
    return {"n_rows": n_rows, "capacity": capacity, "cohort": cohort,
            "windows": windows, "wall_s": wall,
            "rows_per_sec": windows * cohort / wall,
            "quant_bits": quant_bits,
            "cold_row_bytes": store.cold.row_nbytes,
            "n_promoted": store.n_promoted, "n_demoted": store.n_demoted}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--window", type=int, default=16,
                    help="count window: merge cohorts of exactly K "
                         "completions (the acceptance gate's cohort 16)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hot-rows", type=int, default=8,
                    help="tiered-arm hot-tier capacity (< --clients so "
                         "LRU eviction and host round-trips fire)")
    ap.add_argument("--residency-rows", type=int, default=100_000,
                    help="population size for the tiered-store "
                         "residency microbench (0 = skip; not run "
                         "under --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (< 30 s); exits non-zero unless "
                         "the store arm beats dict-of-pytrees events/sec "
                         "at cohort 16, the three f32 arms (dict, dense "
                         "store, tiered residency) produce bit-identical "
                         "histories, and the quant8 arm shrinks resident "
                         "store bytes >= 3.5x")
    add_json_arg(ap, "store")
    args = ap.parse_args(argv)

    if args.smoke:
        args.clients, args.rounds, args.tau = 32, 16, 8
        args.window = 16
        # the gate compares MEDIAN-of-3 events/sec: one descheduled
        # rep on a noisy 2-core CI box cannot flip the verdict
        args.reps = 3

    fl = FLConfig(n_clients=args.clients, n_tiers=4, tau=args.tau,
                  rounds=args.rounds, mu=0.0, primary_frac=0.7,
                  seed=args.seed, lr=0.003)

    arms = (("dict", dict(use_store=False)),
            ("store", dict(use_store=True)),
            ("tiered", dict(use_store=True,
                            store_capacity=args.hot_rows)),
            ("quant8", dict(use_store=True, quant_bits=8)))

    # warm the arms' jit caches with a throwaway run each (cohort
    # widths are a pure function of (network, fl, window))
    trainer = ManyLeafTrainer()
    for _, kw in arms:
        run_arm(trainer, fl, args.seed, window=args.window, reps=1, **kw)

    results = {}
    hists = {}
    for label, kw in arms:
        results[label], hists[label] = run_arm(
            trainer, fl, args.seed, window=args.window,
            reps=args.reps, **kw)
        r = results[label]
        print(f"[{label:6s}] events={r['events']:4d}  "
              f"wall={r['wall_s']:6.3f}s  "
              f"{r['events_per_sec']:8.1f} ev/s  "
              f"mean_cohort={r['mean_cohort']:5.2f}  "
              f"drains={r['n_drains']:3d}  "
              f"residency={r['residency']}")

    hs, hd, ht = hists["store"], hists["dict"], hists["tiered"]
    hq = hists["quant8"]

    def _same(a, b):
        return (a.rounds == b.rounds and a.times == b.times
                and a.accuracy == b.accuracy)

    identical = _same(hs, hd)
    tiered_identical = _same(ht, hs)
    speedup = (results["store"]["events_per_sec"]
               / results["dict"]["events_per_sec"])
    speedup_median = (results["store"]["events_per_sec_median"]
                      / results["dict"]["events_per_sec_median"])
    micro = stacking_microbench(16)
    # the quant8 arm's history is NOT bit-identical by design; its
    # contract numbers (row-format shrink + modeled uplink bytes) are
    # deterministic functions of the model/config, so compare.py holds
    # them exactly across trajectory entries.
    quant_shrink = (hs.meta["store_bytes_hot"]
                    / hq.meta["store_bytes_hot"])
    results["speedup"] = speedup
    results["speedup_median"] = speedup_median
    results["histories_identical"] = identical
    results["tiered_histories_identical"] = tiered_identical
    results["quant8_bytes_shrink"] = quant_shrink
    results["quant8_bytes_up"] = hq.meta["bytes_up"]
    results["stacking_cohort16"] = micro
    print(f"[bench_store] store/dict events/sec: {speedup:.2f}x "
          f"(median {speedup_median:.2f}x)  "
          f"histories {'IDENTICAL' if identical else 'MISMATCH'}  "
          f"tiered {'IDENTICAL' if tiered_identical else 'MISMATCH'}")
    print(f"[bench_store] cohort-16 snapshot assembly: "
          f"tree_map(stack)={micro['stack_us']:8.1f}us  "
          f"store.gather={micro['store_gather_us']:8.1f}us")
    print(f"[bench_store] quant8 resident bytes shrink: "
          f"{quant_shrink:.2f}x  "
          f"(f32 {hs.meta['store_bytes_hot']} B -> "
          f"int8 {hq.meta['store_bytes_hot']} B, "
          f"uplink {hq.meta['bytes_up']} B modeled)")

    if args.residency_rows > 0 and not args.smoke:
        for key, qb in (("residency", 32), ("residency_int8", 8)):
            res = residency_microbench(args.residency_rows, quant_bits=qb)
            results[key] = res
            print(f"[bench_store] residency N={res['n_rows']} "
                  f"hot={res['capacity']} q{qb}: "
                  f"{res['rows_per_sec']:8.1f} rows/s  "
                  f"cold_row={res['cold_row_bytes']}B  "
                  f"promoted={res['n_promoted']}  "
                  f"demoted={res['n_demoted']}")

    maybe_write_json(args, "store", results, extra_context={
        "store_arm_path": hs.meta.get("store_path"),
        "dict_arm_path": hd.meta.get("store_path"),
        "tiered_residency": ht.meta.get("residency"),
        "kernel_agg": hs.meta.get("kernel_agg"),
    })
    if args.smoke:
        # history identity stays STRICT (bitwise) across all three
        # arms; only the timing comparison is deflaked via the median.
        # The arms must also have RESOLVED to the snapshot paths they
        # claim to measure — the tiered arm must really have run with
        # a hot tier smaller than the population (eviction fired).
        ok = (identical and tiered_identical and speedup_median > 1.0
              and results["store"]["mean_cohort"] > 1.0
              and hs.meta.get("store_path") == "store"
              and hd.meta.get("store_path") == "dict"
              and ht.meta.get("residency") == "tiered-host"
              and ht.meta.get("hot_rows") == args.hot_rows
              and ht.meta.get("hot_rows") < args.clients
              # quant8 arm: claimed row format actually ran, and the
              # int8+meta layout really is >= 3.5x leaner than dense
              # f32 rows (24-leaf model: 3.88x)
              and hq.meta.get("quant_bits") == 8
              and hq.meta.get("store_path") == "store"
              and quant_shrink >= 3.5)
        print(f"[bench_store] smoke {'PASS' if ok else 'FAIL'}")
        raise SystemExit(0 if ok else 1)
    return results


if __name__ == "__main__":
    main()
