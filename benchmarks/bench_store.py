"""A/B benchmark: device-resident ClientStateStore vs dict-of-pytrees.

    PYTHONPATH=src python benchmarks/bench_store.py [--clients 32]
        [--tau 8] [--rounds 16] [--window 16] [--reps 5]
        [--smoke] [--json [PATH]]

Both arms run the SAME event-driven windowed async runtime
(``AsyncRunner``) over the same ``WirelessNetwork`` realization and
update budget; the only difference is where client snapshots live:

* dict  — ``use_store=False``: a ``Dict[int, pytree]`` of N scattered
  model copies, re-stacked leaf by leaf (``tree_map(jnp.stack)``) on
  every drained window (the PR 2 behaviour);
* store — ``use_store=True``: one flat (N, P) device buffer, gathered
  per window and re-scattered by the fused donating merge+scatter
  program (``engine.train_window``).

Histories are bit-identical by construction (asserted every run), so
the harness measures pure server-step overhead: merged client updates
per second over the whole run, plus a snapshot-assembly micro-bench at
cohort 16 ("peak stacking": ``tree_map(jnp.stack)`` over 16 snapshot
pytrees vs one ``store.gather``).

The trainer is a synthetic many-leaf model (24 leaves, ~6k params)
whose cohort step is a single jitted elementwise update: local
training is deliberately cheap so the number isolates the snapshot
gather/stack + merge + re-snapshot path the store replaces.  Real
models shift both arms by the same training time, so the store's win
is a lower bound on nothing and an upper bound on everything — read it
as "server-step overhead shrinks by this factor", not end-to-end
wall-clock.

``--smoke`` is the CI-sized run (< 30 s on 2 CPU cores): exits
non-zero unless windows actually batch (mean cohort > 1), histories
match bit-for-bit, and the store arm beats dict events/sec.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from common import add_json_arg, maybe_write_json, time_fn, timed_reps
from repro.config.base import FLConfig
from repro.core.state import ClientStateStore
from repro.fl.network import WirelessNetwork
from repro.fl.testing import SyntheticCohortTrainer
from repro.runtime.async_loop import AsyncRunner


def ManyLeafTrainer():
    """24-leaf synthetic model (shared trainer-contract implementation
    in ``repro.fl.testing``): enough uniform leaves that leaf-by-leaf
    snapshot stacking dominates the dict arm's server step."""
    return SyntheticCohortTrainer.many_leaf(n_leaves=24, leaf=256)


def run_arm(trainer, fl, seed, *, use_store: bool, window: int,
            reps: int):
    """``reps`` timed runs over identical realizations (the shared
    trainer keeps both arms' jit caches warm after the warmup pass, so
    reps measure steady-state server overhead); best-rep summary +
    median-of-reps gate statistic via ``common.timed_reps``."""
    hists = []

    def once():
        net = WirelessNetwork(fl.n_clients, fl.tier_delay_means,
                              fl.delay_std, fl.mu, fl.failure_delay, seed)
        runner = AsyncRunner(trainer, net, fl, window=window,
                             eval_every=fl.rounds * fl.tau + 1,
                             use_store=use_store)
        t0 = time.perf_counter()
        hist = runner.run()
        wall = time.perf_counter() - t0
        hists.append(hist)
        return wall, sum(runner.cohort_sizes), {
            "mean_cohort": hist.meta["mean_cohort"],
            "n_drains": hist.meta["n_drains"]}

    return timed_reps(once, reps), hists[-1]


def stacking_microbench(cohort: int):
    """Median microseconds to assemble a cohort's start snapshots:
    leaf-by-leaf stacking of ``cohort`` pytrees vs one store gather."""
    trainer = ManyLeafTrainer()
    params = trainer.init_params(0)
    snapshots = [trainer.init_params(i) for i in range(cohort)]

    def stack_arm():
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                      *snapshots)

    store = ClientStateStore(params, cohort)
    for i, s in enumerate(snapshots):
        store.scatter_params([i], s)
    ids = list(range(cohort))

    def gather_arm():
        return store.gather(ids)

    return {"stack_us": time_fn(stack_arm, iters=30),
            "store_gather_us": time_fn(gather_arm, iters=30)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--window", type=int, default=16,
                    help="count window: merge cohorts of exactly K "
                         "completions (the acceptance gate's cohort 16)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (< 30 s); exits non-zero unless "
                         "the store arm beats dict-of-pytrees events/sec "
                         "at cohort 16 with bit-identical histories")
    add_json_arg(ap, "store")
    args = ap.parse_args(argv)

    if args.smoke:
        args.clients, args.rounds, args.tau = 32, 16, 8
        args.window = 16
        # the gate compares MEDIAN-of-3 events/sec: one descheduled
        # rep on a noisy 2-core CI box cannot flip the verdict
        args.reps = 3

    fl = FLConfig(n_clients=args.clients, n_tiers=4, tau=args.tau,
                  rounds=args.rounds, mu=0.0, primary_frac=0.7,
                  seed=args.seed, lr=0.003)

    # warm both arms' jit caches with a throwaway run each (cohort
    # widths are a pure function of (network, fl, window))
    trainer = ManyLeafTrainer()
    for use_store in (False, True):
        run_arm(trainer, fl, args.seed, use_store=use_store,
                window=args.window, reps=1)

    results = {}
    hists = {}
    for label, use_store in (("dict", False), ("store", True)):
        results[label], hists[label] = run_arm(
            trainer, fl, args.seed, use_store=use_store,
            window=args.window, reps=args.reps)
        r = results[label]
        print(f"[{label:5s}] events={r['events']:4d}  "
              f"wall={r['wall_s']:6.3f}s  "
              f"{r['events_per_sec']:8.1f} ev/s  "
              f"mean_cohort={r['mean_cohort']:5.2f}  "
              f"drains={r['n_drains']:3d}")

    hs, hd = hists["store"], hists["dict"]
    identical = (hs.rounds == hd.rounds and hs.times == hd.times
                 and hs.accuracy == hd.accuracy)
    speedup = (results["store"]["events_per_sec"]
               / results["dict"]["events_per_sec"])
    speedup_median = (results["store"]["events_per_sec_median"]
                      / results["dict"]["events_per_sec_median"])
    micro = stacking_microbench(16)
    results["speedup"] = speedup
    results["speedup_median"] = speedup_median
    results["histories_identical"] = identical
    results["stacking_cohort16"] = micro
    print(f"[bench_store] store/dict events/sec: {speedup:.2f}x "
          f"(median {speedup_median:.2f}x)  "
          f"histories {'IDENTICAL' if identical else 'MISMATCH'}")
    print(f"[bench_store] cohort-16 snapshot assembly: "
          f"tree_map(stack)={micro['stack_us']:8.1f}us  "
          f"store.gather={micro['store_gather_us']:8.1f}us")

    maybe_write_json(args, "store", results, extra_context={
        "store_arm_path": hs.meta.get("store_path"),
        "dict_arm_path": hd.meta.get("store_path"),
        "kernel_agg": hs.meta.get("kernel_agg"),
    })
    if args.smoke:
        # history identity stays STRICT (bitwise); only the timing
        # comparison is deflaked via the median.  The arms must also
        # have RESOLVED to the snapshot paths they claim to measure.
        ok = (identical and speedup_median > 1.0
              and results["store"]["mean_cohort"] > 1.0
              and hs.meta.get("store_path") == "store"
              and hd.meta.get("store_path") == "dict")
        print(f"[bench_store] smoke {'PASS' if ok else 'FAIL'}")
        raise SystemExit(0 if ok else 1)
    return results


if __name__ == "__main__":
    main()
