"""A/B benchmark: sequential-merge FedAsync vs windowed-cohort runtime.

    PYTHONPATH=src python benchmarks/bench_async.py [--clients 32]
        [--rounds 4] [--tau 8] [--window-secs 15] [--smoke]

Both arms run the SAME event-driven runtime (repro.runtime) over the
same ``WirelessNetwork`` realization and the same update budget
(rounds * tau merged client updates); the only difference is the
aggregation window:

* sequential — ``window=0``: one merge per completion event, cohorts of
  one (the pre-runtime FedAsync behaviour, history-identical to it);
* windowed   — ``window_secs=T``: every completion landing within T
  virtual seconds of the anchor event drains as ONE vmapped cohort with
  a single fused staleness-weighted merge.

Reported per arm: real wall-clock, merged client updates per second
(events/sec), mean drained cohort size, and the virtual time reached.
Events/sec is the server-step throughput knob the ROADMAP's
"async/overlapped rounds" item asks for: the windowed arm does the same
local-training work but amortizes dispatch + merge over the cohort.

``--smoke`` runs a CI-sized configuration (< 30 s on 2 CPU cores) and
exits non-zero unless the windowed arm actually drains multi-client
cohorts (mean cohort > 1) and beats sequential events/sec.
"""

from __future__ import annotations

import argparse
import time

from common import add_json_arg, maybe_write_json, timed_reps, traced_run
from repro.config import get_arch
from repro.config.base import FLConfig
from repro.fl.client import CNNTrainer
from repro.fl.network import WirelessNetwork
from repro.runtime.async_loop import AsyncRunner


def run_arm(trainer, net, fl, *, window_secs: float, eval_every: int,
            reps: int = 1):
    """Best-rep summary + median-of-reps gate statistic over ``reps``
    timed runs (``common.timed_reps`` — the shared deflaked smoke
    statistic)."""

    def once():
        t0 = time.perf_counter()
        runner = AsyncRunner(trainer, net, fl, window_secs=window_secs,
                             eval_every=eval_every)
        hist = runner.run()
        wall = time.perf_counter() - t0
        return wall, sum(runner.cohort_sizes), {
            "mean_cohort": hist.meta["mean_cohort"],
            "n_drains": hist.meta["n_drains"],
            "virtual_time": hist.times[-1] if hist.times else 0.0,
            "store_path": hist.meta.get("store_path")}

    out = timed_reps(once, reps)
    # phase-time breakdown from ONE extra traced rep (timed reps stay
    # untraced so the A/B statistic is unperturbed)
    out["phases"] = traced_run(once)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--window-secs", type=float, default=15.0)
    ap.add_argument("--mu", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (< 30 s); exits non-zero unless "
                         "windowed cohorts beat sequential merging")
    add_json_arg(ap, "async")
    args = ap.parse_args(argv)

    reps = 1
    if args.smoke:
        # cohort-16 windows: big enough that the vmapped-cohort win is
        # robustly > 1x on a 2-core CI runner, small enough for < 30 s;
        # the gate compares MEDIAN-of-3 events/sec so one noisy timing
        # sample cannot flip the verdict
        args.clients, args.rounds, args.tau = 32, 2, 8
        args.window_secs = 20.0
        reps = 3

    fl = FLConfig(n_clients=args.clients, n_tiers=4, tau=args.tau,
                  rounds=args.rounds, mu=args.mu, primary_frac=0.7,
                  seed=args.seed, lr=0.003)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    trainer = CNNTrainer(get_arch("cnn-mnist").reduced(), fl, "mnist",
                         scale=0.01)
    # evals are not what this harness measures — keep only the terminal
    # one (the runtime always records the final state).
    eval_every = fl.rounds * fl.tau + 1

    # warm the jit caches of BOTH arms with an identical throwaway run
    # (the drained cohort sizes — and hence the compiled vmap widths —
    # are a pure function of (network, fl, window), so the same config
    # warms exactly the programs the timed run needs).
    for w in (0.0, args.window_secs):
        run_arm(trainer, net, fl, window_secs=w, eval_every=eval_every)

    results = {}
    for label, w in (("sequential", 0.0), ("windowed", args.window_secs)):
        results[label] = run_arm(trainer, net, fl, window_secs=w,
                                 eval_every=eval_every, reps=reps)
        r = results[label]
        print(f"[{label:10s}] window_secs={w:5.1f}  "
              f"events={r['events']:4d}  wall={r['wall_s']:6.2f}s  "
              f"{r['events_per_sec']:7.2f} ev/s  "
              f"mean_cohort={r['mean_cohort']:5.2f}  "
              f"drains={r['n_drains']:4d}")
    speedup = (results["windowed"]["events_per_sec"]
               / results["sequential"]["events_per_sec"])
    speedup_median = (results["windowed"]["events_per_sec_median"]
                      / results["sequential"]["events_per_sec_median"])
    results["speedup"] = speedup
    results["speedup_median"] = speedup_median
    print(f"[bench_async] windowed/sequential events/sec: {speedup:.2f}x "
          f"(median {speedup_median:.2f}x)")

    maybe_write_json(args, "async", results, extra_context={
        "windowed_arm_path": results["windowed"].get("store_path"),
        "sequential_arm_path": results["sequential"].get("store_path"),
    })
    if args.smoke:
        ok = (results["windowed"]["mean_cohort"] > 1.0
              and speedup_median > 1.0)
        print(f"[bench_async] smoke {'PASS' if ok else 'FAIL'}")
        raise SystemExit(0 if ok else 1)
    return results


if __name__ == "__main__":
    main()
