"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
JSON records in results/dryrun/."""

from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.common import RESULTS_DIR, add_json_arg, maybe_write_json

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["granite-20b", "nemotron-4-340b", "phi4-mini-3.8b",
              "llama3.2-1b", "mixtral-8x7b", "hubert-xlarge", "hymba-1.5b",
              "arctic-480b", "xlstm-350m", "chameleon-34b"]


def load(dryrun_dir=None):
    d = dryrun_dir or os.path.join(RESULTS_DIR, "dryrun")
    recs = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_row(r):
    if r.get("status") == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped | — | {r['reason'].split('(')[0].strip()} |")
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"ERROR | — | {r.get('error','?')[:40]} |")
    t = r["roofline"]
    return ("| {arch} | {shape} | {mesh} | {c:.4f} | {m:.4f} | {x:.4f} | "
            "{dom} | {u:.2f} | {var} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                c=t["compute_s"], m=t["memory_s"], x=t["collective_s"],
                dom=t["dominant"].replace("_s", ""),
                u=t["useful_ratio"], var=r.get("variant", "")))


def render(mesh="16x16", dryrun_dir=None) -> str:
    recs = load(dryrun_dir)
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
        " | dominant | useful | variant |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r:
                lines.append(fmt_row(r))
    return "\n".join(lines)


def bench_results(mesh="16x16", dryrun_dir=None) -> dict:
    """``BENCH_roofline.json`` results: one row per (arch, shape) with
    the analytic roofline scalars (deterministic given the model)."""
    out = {}
    for (a, s, m), r in sorted(load(dryrun_dir).items()):
        if m != mesh:
            continue
        row = {"status": r.get("status", "?")}
        if r.get("status") == "ok":
            t = r["roofline"]
            row.update(dominant=t["dominant"],
                       useful_ratio=t["useful_ratio"],
                       compute_s=t["compute_s"], memory_s=t["memory_s"],
                       collective_s=t["collective_s"])
        out[f"{a}/{s}"] = row
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--dir", default=None)
    add_json_arg(ap, "roofline")
    a = ap.parse_args()
    print(render(a.mesh, a.dir))
    maybe_write_json(a, "roofline", bench_results(a.mesh, a.dir),
                     extra_context={"mesh": a.mesh})
