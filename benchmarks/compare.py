"""Diff a fresh benchmark ``--json`` result against a committed
``BENCH_*.json`` baseline — the bench-trajectory regression gate.

    python benchmarks/compare.py BASELINE FRESH [--tol 0.6] \
        [--tol-metric SUBSTR=TOL ...] [--skip SUBSTR ...]

Both files are ``benchmarks/common.write_bench_json`` payloads
(``{"bench", "context", "results"}``).  The gate walks the BASELINE's
``results`` tree; every baseline key must exist in the fresh results
(schema-strict — a renamed or vanished metric is a regression even if
nothing got slower), while extra fresh keys are fine (new metrics land
without a baseline refresh).

Values are classified per leaf key, because one tolerance cannot serve
three kinds of number:

* **timing** (``wall_s``, ``*_s``, ``*_us``) — lower is better; fresh
  may be up to ``1/(1-tol)`` x the baseline (default tol 0.6 -> 2.5x:
  CI boxes are noisy and 2-core runners deschedule) before it counts
  as a regression;
* **throughput** (``*_per_sec``, ``*_rate``, ``speedup*``) — higher is
  better, same band mirrored;
* **deterministic** (everything else numeric: event counts, cohort
  sizes, virtual time, promotion counts — all seeded) — must match
  exactly (tiny float epsilon), as must booleans and strings;
* **skipped** (``phases`` subtrees, ``*_samples`` lists, ``jax.*``) —
  presence-checked only; their values vary run to run by construction.

Exit status: 0 = within tolerance, 1 = regression(s), 2 = usage/IO
error.  CI runs this after the smoke benches; refresh a baseline by
re-running the bench with ``--json`` on a quiet machine and committing
the file (see ROADMAP "Telemetry & regression gates").
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

EPS = 1e-9

TIMING_SUFFIXES = ("wall_s", "_us")
# containment, not suffix: "events_per_sec_median" and "speedup_median"
# are throughput statistics too
THROUGHPUT_MARKS = ("per_sec", "_rate", "speedup")
SKIP_KEYS = ("phases",)
SKIP_SUFFIXES = ("_samples",)
SKIP_PREFIXES = ("jax.",)


def classify(key: str) -> str:
    if key in SKIP_KEYS or key.endswith(SKIP_SUFFIXES) \
            or key.startswith(SKIP_PREFIXES):
        return "skip"
    if any(m in key for m in THROUGHPUT_MARKS):
        return "throughput"
    # timing AFTER throughput: "events_per_sec" must not match "_s"
    if key.endswith(TIMING_SUFFIXES) or key.endswith("_s"):
        return "timing"
    return "exact"


class Gate:
    def __init__(self, tol: float, tol_overrides: Dict[str, float],
                 skips: List[str]):
        self.tol = tol
        self.tol_overrides = tol_overrides
        self.skips = skips
        self.checks: List[Tuple[str, str, str]] = []   # (path, status, note)
        self.failures = 0

    def _emit(self, path: str, status: str, note: str = ""):
        self.checks.append((path, status, note))
        if status == "FAIL":
            self.failures += 1

    def _tol_for(self, path: str) -> float:
        for sub, t in self.tol_overrides.items():
            if sub in path:
                return t
        return self.tol

    def _skipped(self, path: str) -> bool:
        return any(sub in path for sub in self.skips)

    def compare(self, base, fresh, path: str = "results"):
        key = path.rsplit(".", 1)[-1]
        if self._skipped(path) or classify(key) == "skip":
            self._emit(path, "skip")
            return
        if isinstance(base, dict):
            if not isinstance(fresh, dict):
                self._emit(path, "FAIL",
                           f"baseline is a dict, fresh is "
                           f"{type(fresh).__name__}")
                return
            for k, v in base.items():
                if k in SKIP_KEYS or k.endswith(SKIP_SUFFIXES) \
                        or k.startswith(SKIP_PREFIXES):
                    child = f"{path}.{k}"
                    if k in fresh:
                        self._emit(child, "skip")
                    else:
                        self._emit(child, "FAIL", "missing in fresh results")
                    continue
                child = f"{path}.{k}"
                if k not in fresh:
                    self._emit(child, "FAIL", "missing in fresh results")
                    continue
                self.compare(v, fresh[k], child)
            return
        if isinstance(base, list):
            # series/samples: schema presence only (lengths may differ
            # with rep counts); element values are run noise
            self._emit(path, "skip")
            return
        if isinstance(base, bool) or isinstance(base, str):
            if base != fresh:
                self._emit(path, "FAIL", f"{base!r} -> {fresh!r}")
            else:
                self._emit(path, "ok")
            return
        if isinstance(base, (int, float)):
            if not isinstance(fresh, (int, float)) \
                    or isinstance(fresh, bool):
                self._emit(path, "FAIL",
                           f"baseline number, fresh "
                           f"{type(fresh).__name__}")
                return
            kind = classify(key)
            if kind == "exact":
                scale = max(abs(base), abs(fresh), 1.0)
                if abs(base - fresh) > EPS * scale:
                    self._emit(path, "FAIL",
                               f"deterministic metric drifted: "
                               f"{base} -> {fresh}")
                else:
                    self._emit(path, "ok")
                return
            tol = self._tol_for(path)
            band = 1.0 / max(1.0 - tol, 1e-9)
            if kind == "timing":
                worse = (fresh / base) if base > 0 else 1.0
                arrow = f"{base:.4g}s -> {fresh:.4g}s"
            else:
                worse = (base / fresh) if fresh > 0 else float("inf")
                arrow = f"{base:.4g} -> {fresh:.4g}"
            if worse > band:
                self._emit(path, "FAIL",
                           f"{kind} regressed {worse:.2f}x "
                           f"(allowed {band:.2f}x): {arrow}")
            else:
                self._emit(path, "ok", f"{worse:.2f}x of allowed "
                                       f"{band:.2f}x")
            return
        self._emit(path, "skip", f"unhandled type {type(base).__name__}")


def load_payload(path: str) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    for k in ("bench", "results"):
        if k not in doc:
            raise ValueError(f"{path}: not a write_bench_json payload "
                             f"(missing {k!r})")
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python benchmarks/compare.py",
        description="Benchmark-trajectory regression gate: compare a "
                    "fresh --json result against a committed baseline.")
    ap.add_argument("baseline", help="committed BENCH_*.json")
    ap.add_argument("fresh", help="freshly produced --json output")
    ap.add_argument("--tol", type=float, default=0.6,
                    help="relative tolerance for timing/throughput "
                         "metrics; the allowed worse-ratio is "
                         "1/(1-tol) (default 0.6 -> 2.5x)")
    ap.add_argument("--tol-metric", action="append", default=[],
                    metavar="SUBSTR=TOL",
                    help="per-metric tolerance override for any path "
                         "containing SUBSTR (repeatable)")
    ap.add_argument("--skip", action="append", default=[],
                    metavar="SUBSTR",
                    help="skip any metric path containing SUBSTR "
                         "(repeatable)")
    ap.add_argument("--verbose", action="store_true",
                    help="print every check, not just failures")
    args = ap.parse_args(argv)

    overrides = {}
    for spec in args.tol_metric:
        sub, _, t = spec.partition("=")
        try:
            overrides[sub] = float(t)
        except ValueError:
            print(f"compare: bad --tol-metric {spec!r}", file=sys.stderr)
            return 2
    try:
        base = load_payload(args.baseline)
        fresh = load_payload(args.fresh)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"compare: {e}", file=sys.stderr)
        return 2
    if base["bench"] != fresh["bench"]:
        print(f"compare: bench mismatch: baseline is "
              f"{base['bench']!r}, fresh is {fresh['bench']!r}",
              file=sys.stderr)
        return 2

    gate = Gate(args.tol, overrides, args.skip)
    gate.compare(base["results"], fresh["results"])

    n_ok = sum(1 for _, s, _ in gate.checks if s == "ok")
    n_skip = sum(1 for _, s, _ in gate.checks if s == "skip")
    for path, status, note in gate.checks:
        if status == "FAIL" or args.verbose:
            print(f"[{status:>4}] {path}" + (f"  {note}" if note else ""))
    verdict = "PASS" if gate.failures == 0 else "FAIL"
    print(f"[compare] {base['bench']}: {verdict} "
          f"({n_ok} ok, {n_skip} skipped, {gate.failures} regressed; "
          f"tol={args.tol})")
    return 0 if gate.failures == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
