import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf arctic v4: 16-step serve loop — does XLA hoist the FSDP weight
gathers out of the decode scan (amortizing them across tokens)?

    PYTHONPATH=src python -m benchmarks.perf_serve_loop

Roofline one-off: writes its own results/perf/ records and stays
outside the ``BENCH_*.json`` / ``compare.py`` bench trajectory.
"""

import json

import jax

from repro.config import get_arch
from repro.config.base import INPUT_SHAPES, TrainConfig
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.roofline import analyze_hlo, roofline_terms
from repro.sharding import (batch_specs, decode_state_specs, named_shardings,
                            param_specs)
from repro.sharding.hints import set_mesh

N_STEPS = 16


def run(arch, shape_name, fsdp: bool):
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    tcfg = TrainConfig(context_parallel="never", seq_parallel=False,
                       long_ctx_swa=True, decode_headdim_shard=False,
                       fsdp=fsdp)
    mesh = make_production_mesh()
    set_mesh(mesh)
    params = steps_lib.abstract_params(cfg, tcfg)
    p_sh = named_shardings(param_specs(params, mesh, fsdp=tcfg.fsdp), mesh)
    state = steps_lib.abstract_decode_state(cfg, shape, tcfg)
    s_sh = named_shardings(decode_state_specs(state, mesh), mesh)
    batch = steps_lib.input_specs(cfg, shape, tcfg)
    b_sh = named_shardings(batch_specs(batch, mesh), mesh)
    loop = steps_lib.make_serve_loop(cfg, shape, tcfg, n_steps=N_STEPS)
    fn = jax.jit(loop, in_shardings=(p_sh, s_sh, b_sh),
                 out_shardings=(None, s_sh))
    with mesh:
        compiled = fn.lower(params, state, batch).compile()
    set_mesh(None)
    hlo = analyze_hlo(compiled.as_text())
    terms = roofline_terms(
        hlo_flops=hlo["dot_flops"],
        hbm_bytes=0.0,
        collective_bytes=hlo["collective_wire_bytes"], chips=1)
    per_tok = terms["collective_s"] / N_STEPS
    print(f"[serve_loop] {arch} {shape_name} fsdp={fsdp}: "
          f"collective {terms['collective_s']:.4f}s / {N_STEPS} steps "
          f"= {per_tok:.4f}s/token", flush=True)
    return {"arch": arch, "shape": shape_name, "fsdp": fsdp,
            "n_steps": N_STEPS, "collective_s_total": terms["collective_s"],
            "collective_s_per_token": per_tok,
            "collective_breakdown": hlo["collective_breakdown"]}


def main():
    out = []
    for fsdp in (True, False):
        out.append(run("arctic-480b", "long_500k", fsdp))
    os.makedirs("benchmarks/results/perf", exist_ok=True)
    with open("benchmarks/results/perf/arctic-480b_long_500k_v4_serveloop.json",
              "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
