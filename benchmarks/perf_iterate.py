import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lowers the three chosen (arch x shape)
pairs under cumulative optimization variants and records the roofline
deltas.  Baselines (v0) are the cached dry-run records.

    PYTHONPATH=src python -m benchmarks.perf_iterate [--target all]

Roofline one-off: writes its own results/perf/ records and stays
outside the ``BENCH_*.json`` / ``compare.py`` bench trajectory.
"""

import argparse
import dataclasses
import json

from repro.config.base import TrainConfig

BASE = TrainConfig(context_parallel="never", seq_parallel=False,
                   long_ctx_swa=False, decode_headdim_shard=False)

# target -> list of (variant_name, tcfg, module_toggles)
PLANS = {
    "llama3.2-1b/train_4k": [
        ("v1_seq_parallel",
         dataclasses.replace(BASE, seq_parallel=True), {}),
        ("v2_seqpar_nofsdp",
         dataclasses.replace(BASE, seq_parallel=True, fsdp=False), {}),
        ("v3_seqpar_noremat",
         dataclasses.replace(BASE, seq_parallel=True, remat=False), {}),
        ("v4_seqpar_ckv4096",
         dataclasses.replace(BASE, seq_parallel=True, attn_chunk_kv=4096),
         {}),
        # v5+: after the head-sharding rule fix (rules.py: head d-dim no
        # longer FSDP-sharded -> loss logits all-reduce eliminated)
        ("v5_headfix", BASE, {}),
        ("v6_headfix_seqpar",
         dataclasses.replace(BASE, seq_parallel=True), {}),
        ("v7_headfix_noremat",
         dataclasses.replace(BASE, remat=False), {}),
        # v8: napkin math — 1.5B params at global batch 256 doesn't need
        # TP at all; pure ZeRO-3 over all 256 chips predicts wire cost
        # ~3x params ~ 9 GB/dev ~ 0.18 s vs 2.9 s baseline.
        ("v8_pure_fsdp",
         dataclasses.replace(BASE, parallelism="fsdp_only"), {}),
        ("v9_pure_fsdp_noremat",
         dataclasses.replace(BASE, parallelism="fsdp_only", remat=False),
         {}),
    ],
    "phi4-mini-3.8b/prefill_32k": [
        ("v1_context_parallel",
         dataclasses.replace(BASE, context_parallel="auto"), {}),
        ("v2_cp_ckv2048",
         dataclasses.replace(BASE, context_parallel="auto",
                             attn_chunk_kv=2048), {}),
        ("v3_cp_seqpar",
         dataclasses.replace(BASE, context_parallel="auto",
                             seq_parallel=True), {}),
        ("v4_cp_seqpar_cq1024",
         dataclasses.replace(BASE, context_parallel="auto",
                             seq_parallel=True, attn_chunk_q=1024), {}),
    ],
    "arctic-480b/long_500k": [
        ("v1_swa8192",
         dataclasses.replace(BASE, long_ctx_swa=True), {}),
        ("v2_swa_headdim",
         dataclasses.replace(BASE, long_ctx_swa=True,
                             decode_headdim_shard=True), {}),
        ("v3_swa_headdim_nofsdp",
         dataclasses.replace(BASE, long_ctx_swa=True, fsdp=False,
                             decode_headdim_shard=True), {}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="all")
    ap.add_argument("--out", default="benchmarks/results/perf")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    from repro.launch.dryrun import run_one

    for target, plan in PLANS.items():
        if args.target != "all" and args.target != target:
            continue
        arch, shape = target.split("/")
        for name, tcfg, toggles in plan:
            tag = f"{arch}_{shape}_{name}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[perf] {tag}: cached")
                continue
            try:
                rec = run_one(arch, shape, multi_pod=False, tcfg=tcfg,
                              verbose=False)
                rec["variant_name"] = name
                rec["tcfg"] = {k: getattr(tcfg, k) for k in
                               ("context_parallel", "seq_parallel",
                                "long_ctx_swa", "fsdp", "remat",
                                "attn_chunk_q", "attn_chunk_kv",
                                "decode_headdim_shard", "parallelism")}
                rec["toggles"] = toggles
                t = rec["roofline"]
                print(f"[perf] {tag}: dom={t['dominant']} "
                      f"bound={t['bound_s']:.4f}s "
                      f"c={t['compute_s']:.3f} m={t['memory_s']:.3f} "
                      f"x={t['collective_s']:.3f} "
                      f"useful={t['useful_ratio']:.2f}", flush=True)
            except Exception as e:  # fedlint: disable=FED007 -- perf sweep records the variant failure and continues
                import traceback
                rec = {"variant_name": name, "status": "error",
                       "error": repr(e),
                       "trace": traceback.format_exc()[-1500:]}
                print(f"[perf] {tag}: ERROR {e}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
