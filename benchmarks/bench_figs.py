"""Paper Figs. 5-9: non-iid sweep, failure-probability sweep, complex
network, stable network, tier trace.  One function per figure; ``--ci``
scales sizes down for a single CPU."""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import (RESULTS_DIR, add_json_arg, maybe_write_json,
                               run_fl_experiment)

METHODS = ["fedavg", "tifl", "fedasync", "feddct"]


def _save(name, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1)


def fig5_noniid(ci=True):
    """CIFAR/#: data-heterogeneity sweep at mu=0.1 (paper Fig. 5)."""
    s = dict(rounds=20, n_clients=20, tau=3, scale=0.02) if ci else \
        dict(rounds=250, n_clients=50, tau=5, scale=0.2)
    arch = "cnn-mnist" if ci else "resnet8-cifar10"
    out = {}
    for frac in (0.1, 0.3, 0.7):         # 0.1 ~ iid
        for m in METHODS:
            h = run_fl_experiment(arch=arch, method=m, mu=0.1,
                                  primary_frac=frac, **s)
            out[f"{m}_frac{frac}"] = {"acc": h.accuracy, "t": h.times}
            print(f"[fig5] frac={frac} {m:9s} best={h.best_accuracy():.4f}",
                  flush=True)
    _save("fig5_noniid", out)
    return out


def fig6_mu(ci=True):
    """Failure-probability sweep (paper Fig. 6)."""
    s = dict(rounds=20, n_clients=20, tau=3, scale=0.02) if ci else \
        dict(rounds=250, n_clients=50, tau=5, scale=0.2)
    arch = "cnn-mnist" if ci else "resnet8-cifar10"
    out = {}
    for mu in (0.0, 0.2, 0.4):
        for m in METHODS:
            h = run_fl_experiment(arch=arch, method=m, mu=mu,
                                  primary_frac=0.5, **s)
            out[f"{m}_mu{mu}"] = {"acc": h.accuracy, "t": h.times}
            print(f"[fig6] mu={mu} {m:9s} best={h.best_accuracy():.4f} "
                  f"T={h.times[-1]:.0f}s", flush=True)
    _save("fig6_mu", out)
    return out


def fig7_complex(ci=True):
    """Wider resource spread: delays {1,3,10,30,100} (paper Fig. 7)."""
    s = dict(rounds=20, n_clients=20, tau=3, scale=0.02) if ci else \
        dict(rounds=250, n_clients=50, tau=5, scale=0.2)
    out = {}
    for m in METHODS:
        h = run_fl_experiment(arch="cnn-fmnist", method=m, mu=0.1,
                              primary_frac=0.7,
                              tier_delay_means=(1.0, 3.0, 10.0, 30.0, 100.0),
                              **s)
        out[m] = {"acc": h.accuracy, "t": h.times}
        print(f"[fig7] {m:9s} best={h.best_accuracy():.4f} "
              f"T={h.times[-1]:.0f}s", flush=True)
    _save("fig7_complex", out)
    return out


def fig8_stable(ci=True):
    """Stable network (mu=0): isolates the cross-tier selection gain
    (paper Fig. 8)."""
    s = dict(rounds=20, n_clients=20, tau=3, scale=0.02) if ci else \
        dict(rounds=250, n_clients=50, tau=5, scale=0.2)
    out = {}
    for m in METHODS:
        h = run_fl_experiment(arch="cnn-mnist", method=m, mu=0.0,
                              primary_frac=0.7, **s)
        out[m] = {"acc": h.accuracy, "t": h.times}
        print(f"[fig8] {m:9s} best={h.best_accuracy():.4f} "
              f"T={h.times[-1]:.0f}s", flush=True)
    _save("fig8_stable", out)
    return out


def fig9_tier_trace(ci=True):
    """Selected-tier trend over training (paper Fig. 9)."""
    s = dict(rounds=40, n_clients=20, tau=3, scale=0.02) if ci else \
        dict(rounds=400, n_clients=50, tau=5, scale=0.2)
    h = run_fl_experiment(arch="cnn-mnist", method="feddct", mu=0.1,
                          primary_frac=0.7, **s)
    # linear fit like the paper
    t = np.arange(len(h.tier))
    slope = float(np.polyfit(t, h.tier, 1)[0]) if len(h.tier) > 3 else 0.0
    out = {"tier": h.tier, "rounds": h.rounds, "slope": slope}
    print(f"[fig9] tier trace slope={slope:+.4f} "
          f"(paper: positive — tiers drift up)", flush=True)
    _save("fig9_tier_trace", out)
    return out


ALL = {"fig5": fig5_noniid, "fig6": fig6_mu, "fig7": fig7_complex,
       "fig8": fig8_stable, "fig9": fig9_tier_trace}


def _bench_summary(name, out):
    """Compact per-figure scalars for ``BENCH_figs.json`` (the full
    trajectories stay in results/): seeded-deterministic, so the
    compare gate checks them exactly."""
    if name == "fig9":
        return {"slope": out["slope"], "n_rounds": len(out["tier"])}
    return {k: {"best_acc": max(v["acc"]) if v["acc"] else 0.0,
                "final_virtual_time": v["t"][-1] if v["t"] else 0.0}
            for k, v in out.items()}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    add_json_arg(ap, "figs")
    a = ap.parse_args()
    results = {}
    for name, fn in ALL.items():
        if a.only and name != a.only:
            continue
        results[name] = _bench_summary(name, fn(ci=not a.full))
    maybe_write_json(a, "figs", results,
                     extra_context={"full": a.full, "only": a.only})
