"""Shared benchmark helpers: FL experiment runner, timing utilities,
and the machine-readable ``BENCH_<name>.json`` trajectory writer every
A/B harness feeds (so future PRs can diff throughput numbers instead
of re-reading log lines)."""

from __future__ import annotations

import json
import os
import platform
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.network import WirelessNetwork

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


# ---------------------------------------------------------------------------
# machine-readable benchmark trajectories
# ---------------------------------------------------------------------------

def add_json_arg(ap, name: str):
    """Register ``--json [PATH]`` on an argparse parser: write the
    harness results as ``BENCH_<name>.json`` next to the benchmarks
    (or to an explicit PATH)."""
    ap.add_argument(
        "--json", nargs="?", const="", default=None, metavar="PATH",
        help=f"write machine-readable results (default "
             f"benchmarks/BENCH_{name}.json; pass PATH to override)")


def write_bench_json(name: str, results: Dict, path: Optional[str] = None,
                     extra_context: Optional[Dict] = None) -> str:
    """Dump one benchmark run as ``{"bench", "context", "results"}``.

    ``results`` is the harness's own dict (arms, speedups, gates);
    ``context`` records enough environment to compare trajectories
    across PRs.  ``extra_context`` lets a harness record run-resolved
    facts the argv cannot show — e.g. which snapshot path
    (store/dict) and merge dispatch (kernel/jnp) actually ran — so
    trajectory points stay comparable across PRs that change the
    defaults.  Returns the path written."""
    out = path or os.path.join(os.path.dirname(__file__),
                               f"BENCH_{name}.json")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    import jax
    context = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "argv": sys.argv[1:],
    }
    context.update(extra_context or {})
    payload = {"bench": name, "context": context, "results": results}
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"[{name}] json -> {out}")
    return out


def maybe_write_json(args, name: str, results: Dict,
                     extra_context: Optional[Dict] = None):
    """Honor ``add_json_arg``'s flag if the caller passed it."""
    if getattr(args, "json", None) is not None:
        write_bench_json(name, results, path=args.json or None,
                         extra_context=extra_context)


def run_fl_experiment(*, arch: str, method: str, mu: float,
                      primary_frac: float, rounds: int, n_clients: int = 50,
                      tau: int = 5, n_tiers: int = 5, scale: float = 0.05,
                      seed: int = 0, lr: float = 0.003,
                      tier_delay_means=(5.0, 10.0, 15.0, 20.0, 25.0),
                      target_accuracy: float = 0.0, eval_every: int = 1,
                      tag: Optional[str] = None, force: bool = False):
    """Run one (method x setting) cell with caching to results/fl/."""
    tag = tag or (f"{method}_{arch}_mu{mu}_frac{primary_frac}_r{rounds}"
                  f"_c{n_clients}_s{seed}_sc{scale}"
                  f"_d{'-'.join(str(x) for x in tier_delay_means)}")
    os.makedirs(os.path.join(RESULTS_DIR, "fl"), exist_ok=True)
    path = os.path.join(RESULTS_DIR, "fl", tag + ".json")
    if os.path.exists(path) and not force:
        from repro.fl.metrics import RunHistory
        return RunHistory.load(path)
    fl = FLConfig(n_clients=n_clients, n_tiers=n_tiers, tau=tau,
                  rounds=rounds, mu=mu, primary_frac=primary_frac,
                  seed=seed, lr=lr, tier_delay_means=tuple(tier_delay_means),
                  target_accuracy=target_accuracy)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    trainer = build_fl_clients(arch, fl, scale=scale)
    hist = run_method(method, trainer, net, fl, eval_every=eval_every)
    hist.save(path)
    return hist


def timed_reps(run_once, reps: int) -> Dict:
    """Shared deflaked-arm summary for the A/B harnesses.

    ``run_once()`` -> ``(wall_s, events, extra_dict)`` for one timed
    run.  Returns the BEST rep's numbers (the low-noise headline)
    merged with its extras, plus ``events_per_sec_median`` across reps
    — the smoke-gate statistic (a single descheduled rep on a busy
    2-core CI box can invert a best-of comparison) — and the raw
    ``events_per_sec_samples``.  One definition keeps every harness's
    gate measuring the same statistic."""
    samples: List[float] = []
    best = None
    for _ in range(reps):
        wall, events, extra = run_once()
        eps = events / wall
        samples.append(eps)
        if best is None or eps > best["events_per_sec"]:
            best = {"wall_s": wall, "events": events,
                    "events_per_sec": eps, **extra}
    best["events_per_sec_median"] = float(np.median(samples))
    best["events_per_sec_samples"] = samples
    return best


def traced_run(run_once) -> Dict:
    """One EXTRA telemetry-enabled repetition of a harness arm.

    The timed reps stay untraced (tracing's bookkeeping, however
    cheap, must not perturb the A/B statistic); this runs the arm once
    more under ``repro.obs`` and returns the phase-time breakdown —
    total host seconds per span name — plus the counters and derived
    rates, for the ``BENCH_*.json`` trajectory."""
    from repro import obs
    with obs.tracing() as tel:
        run_once()
    s = tel.summary()
    out = {"phase_s": {name: agg["total_s"]
                       for name, agg in sorted(s["spans"].items())},
           "counters": s["counters"]}
    if "rates" in s:
        out["rates"] = s["rates"]
    return out


def time_fn(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall microseconds per call (pre-jitted fns)."""
    import jax
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
