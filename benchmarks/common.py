"""Shared benchmark helpers: FL experiment runner + timing utilities."""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.network import WirelessNetwork

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def run_fl_experiment(*, arch: str, method: str, mu: float,
                      primary_frac: float, rounds: int, n_clients: int = 50,
                      tau: int = 5, n_tiers: int = 5, scale: float = 0.05,
                      seed: int = 0, lr: float = 0.003,
                      tier_delay_means=(5.0, 10.0, 15.0, 20.0, 25.0),
                      target_accuracy: float = 0.0, eval_every: int = 1,
                      tag: Optional[str] = None, force: bool = False):
    """Run one (method x setting) cell with caching to results/fl/."""
    tag = tag or (f"{method}_{arch}_mu{mu}_frac{primary_frac}_r{rounds}"
                  f"_c{n_clients}_s{seed}_sc{scale}"
                  f"_d{'-'.join(str(x) for x in tier_delay_means)}")
    os.makedirs(os.path.join(RESULTS_DIR, "fl"), exist_ok=True)
    path = os.path.join(RESULTS_DIR, "fl", tag + ".json")
    if os.path.exists(path) and not force:
        from repro.fl.metrics import RunHistory
        return RunHistory.load(path)
    fl = FLConfig(n_clients=n_clients, n_tiers=n_tiers, tau=tau,
                  rounds=rounds, mu=mu, primary_frac=primary_frac,
                  seed=seed, lr=lr, tier_delay_means=tuple(tier_delay_means),
                  target_accuracy=target_accuracy)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    trainer = build_fl_clients(arch, fl, scale=scale)
    hist = run_method(method, trainer, net, fl, eval_every=eval_every)
    hist.save(path)
    return hist


def time_fn(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall microseconds per call (pre-jitted fns)."""
    import jax
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
