"""Assemble the data-driven sections of EXPERIMENTS.md from results JSON.

    PYTHONPATH=src python -m benchmarks.make_experiments_report

Emits markdown for §Repro (Table 2, Figs 5-9), §Dry-run, §Roofline and
§Perf from benchmarks/results/{fl,table2.json,fig*.json,dryrun,perf}.
The narrative sections of EXPERIMENTS.md wrap around these tables.
"""

from __future__ import annotations

import glob
import json
import os


from benchmarks.common import RESULTS_DIR
from benchmarks.roofline_table import render as render_roofline


def _load(name):
    p = os.path.join(RESULTS_DIR, name)
    return json.load(open(p)) if os.path.exists(p) else None


def section_table2() -> str:
    rows = _load("table2.json")
    if not rows:
        return "_table2.json missing — run `python -m benchmarks.bench_table2`_"
    out = ["| dataset | method | best acc | time->target (s) | total virtual (s) |",
           "|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['dataset']} | {r['method']} | {r['best_acc']:.4f} "
                   f"| {r['time_to_target_s'] if r['time_to_target_s'] else '—'} "
                   f"| {r['total_time_s']} |")
    return "\n".join(out)


def section_figs() -> str:
    blocks = []
    for fig, label in (("fig5_noniid", "Fig. 5 (# sweep, best acc)"),
                       ("fig6_mu", "Fig. 6 (mu sweep, best acc / total time)"),
                       ("fig7_complex", "Fig. 7 (complex network)"),
                       ("fig8_stable", "Fig. 8 (stable network)")):
        d = _load(fig + ".json")
        if not d:
            continue
        rows = [f"**{label}**", "", "| cell | best acc | total time (s) |",
                "|---|---|---|"]
        for k, v in d.items():
            acc = max(v["acc"]) if v.get("acc") else 0.0
            t = v["t"][-1] if v.get("t") else 0.0
            rows.append(f"| {k} | {acc:.4f} | {t:.0f} |")
        blocks.append("\n".join(rows))
    f9 = _load("fig9_tier_trace.json")
    if f9:
        blocks.append(f"**Fig. 9 (tier trace)**: slope={f9['slope']:+.4f} "
                      f"per round over {len(f9['tier'])} rounds "
                      f"(paper: positive trend — selected tier drifts up). "
                      f"trace={f9['tier'][:25]}…")
    return "\n\n".join(blocks)


def section_dryrun_summary() -> str:
    recs = [json.load(open(p)) for p in
            glob.glob(os.path.join(RESULTS_DIR, "dryrun", "*.json"))]
    ok = [r for r in recs if r.get("status") == "ok"]
    sk = [r for r in recs if r.get("status") == "skipped"]
    er = [r for r in recs if r.get("status") == "error"]
    lines = [f"- combos compiled OK: **{len(ok)}** "
             f"(both 16x16 and 2x16x16 meshes)",
             f"- combos skipped by design: **{len(sk)}** "
             f"(hubert-xlarge decode_32k/long_500k x 2 meshes — "
             f"encoder-only, no decode step)",
             f"- errors: **{len(er)}**"]
    if ok:
        worst_mem = max(ok, key=lambda r: r["memory"]["temp_bytes_per_device"])
        lines.append(
            f"- largest temp footprint: {worst_mem['arch']}/"
            f"{worst_mem['shape']}/{worst_mem['mesh']}: "
            f"{worst_mem['memory']['temp_bytes_per_device']/1e9:.1f} GB/device")
        slow = max(ok, key=lambda r: r.get("compile_s", 0))
        lines.append(f"- slowest compile: {slow['arch']}/{slow['shape']} "
                     f"{slow.get('compile_s')}s")
    return "\n".join(lines)


def section_perf() -> str:
    recs = {}
    for p in sorted(glob.glob(os.path.join(RESULTS_DIR, "perf", "*.json"))):
        r = json.load(open(p))
        recs[os.path.basename(p)[:-5]] = r
    if not recs:
        return "_no perf records — run `python -m benchmarks.perf_iterate`_"
    out = ["| variant | dominant | bound (s) | compute | memory | collective"
           " | useful |", "|---|---|---|---|---|---|---|"]
    for tag, r in recs.items():
        if r.get("status") == "error":
            out.append(f"| {tag} | ERROR | — | — | — | — | — |")
            continue
        t = r["roofline"]
        out.append(f"| {tag} | {t['dominant'].replace('_s','')} "
                   f"| {t['bound_s']:.4f} | {t['compute_s']:.3f} "
                   f"| {t['memory_s']:.3f} | {t['collective_s']:.3f} "
                   f"| {t['useful_ratio']:.2f} |")
    return "\n".join(out)


def main():
    print("## §Repro-Table2\n")
    print(section_table2())
    print("\n## §Figs\n")
    print(section_figs())
    print("\n## §Dry-run summary\n")
    print(section_dryrun_summary())
    print("\n## §Roofline (16x16 single-pod baseline)\n")
    print(render_roofline("16x16"))
    print("\n## §Roofline (2x16x16 multi-pod)\n")
    print(render_roofline("2x16x16"))
    print("\n## §Perf variants\n")
    print(section_perf())


if __name__ == "__main__":
    main()
