"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV.  The fast micro-suite times the
framework's hot paths (aggregation kernel, attention paths, SSM scan,
tiering/selection control plane, CNN train step) and summarizes the
paper-figure experiments if their cached results exist.  ``--paper``
additionally runs the Table-2 + Fig-5..9 reproductions (CI scale).

``--json`` writes the micro-suite timings as ``BENCH_micro.json``
(``benchmarks/common.write_bench_json`` payload), joining the
``compare.py`` bench trajectory: every ``<name>_us`` key lands in the
timing band, the ``derived`` annotations ride in the context block,
and the cache-dependent dryrun summary stays out of the gated results
(its presence varies with ``results/dryrun``).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (RESULTS_DIR, add_json_arg, maybe_write_json,
                               time_fn)


def bench_fedagg():
    from repro.core.aggregation import weighted_average
    n, p = 25, 500_000
    rng = np.random.default_rng(0)
    updates = [{"w": jnp.asarray(rng.normal(size=p).astype(np.float32))}
               for _ in range(n)]
    sizes = list(rng.uniform(50, 150, n))
    us = time_fn(lambda: weighted_average(updates, sizes)["w"], iters=10)
    yield ("fedagg_jnp_25x500k", us, f"{n*p*4/1e6:.0f}MB_reduced")
    from repro.kernels import fedagg_op
    flat = jnp.stack([u["w"] for u in updates])
    us2 = time_fn(lambda: fedagg_op(flat, jnp.asarray(sizes, jnp.float32)),
                  iters=3, warmup=1)
    yield ("fedagg_pallas_interp_25x500k", us2, "interpret_mode")


def bench_attention():
    from repro.models.attention import (banded_attention, chunked_attention,
                                        naive_attention)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, d = 1, 1024, 8, 64
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    fn_n = jax.jit(lambda q, k, v: naive_attention(q, k, v, causal=True))
    fn_c = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, chunk_q=256, chunk_kv=256))
    fn_b = jax.jit(lambda q, k, v: banded_attention(
        q, k, v, window=256, chunk_q=256, chunk_kv=256))
    flops = 4 * b * h * s * s * d / 2
    yield ("attn_naive_1k", time_fn(lambda: fn_n(q, k, v), iters=10),
           f"{flops/1e9:.1f}GF")
    yield ("attn_flashchunked_1k", time_fn(lambda: fn_c(q, k, v), iters=10),
           f"{flops/1e9:.1f}GF")
    yield ("attn_banded_w256_1k", time_fn(lambda: fn_b(q, k, v), iters=10),
           "O(S*W)")


def bench_ssm():
    from repro.models.ssm import init_ssm, ssm_forward
    p = init_ssm(jax.random.PRNGKey(0), 256, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 256), jnp.float32)
    fn = jax.jit(lambda x: ssm_forward(p, x, n_state=16, chunk=128)[0])
    yield ("ssm_chunked_512x512", time_fn(lambda: fn(x), iters=10), "chunk128")


def bench_mlstm():
    from repro.models.xlstm import init_mlstm, mlstm_block
    p = init_mlstm(jax.random.PRNGKey(0), 256, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 256), jnp.float32)
    fn = jax.jit(lambda x: mlstm_block(p, x, 4, chunk=128)[0])
    yield ("mlstm_chunkwise_512", time_fn(lambda: fn(x), iters=10), "chunk128")


def bench_control_plane():
    from repro.core.selection import cstt
    from repro.core.tiering import tiering
    rng = np.random.default_rng(0)
    at = {c: float(rng.uniform(1, 30)) for c in range(1000)}
    ct = {c: int(rng.integers(0, 50)) for c in range(1000)}
    import time as _t
    t0 = _t.perf_counter()
    for _ in range(100):
        ts = tiering(at, 200)
    us = (_t.perf_counter() - t0) / 100 * 1e6
    yield ("tiering_1000clients", us, "alg3")
    ts = tiering(at, 200)
    t0 = _t.perf_counter()
    for i in range(100):
        cstt(3, 0.5, 0.6, ts, at, ct, 5, 1.2, 30.0,
             np.random.default_rng(i))
    us = (_t.perf_counter() - t0) / 100 * 1e6
    yield ("cstt_1000clients", us, "alg4")


def bench_cnn_step():
    from repro.config import get_arch
    from repro.models.cnn import cnn_loss, init_cnn
    cfg = get_arch("cnn-mnist")
    params = init_cnn(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 28, 28, 1))
    y = jnp.zeros((10,), jnp.int32)
    grad = jax.jit(jax.grad(lambda p: cnn_loss(cfg, p, {"x": x, "y": y})))
    yield ("cnn_mnist_grad_b10", time_fn(lambda: grad(params), iters=10),
           "paper_batch")


def bench_lm_step():
    from repro.config import get_arch
    from repro.config.base import TrainConfig
    from repro.launch.steps import make_train_step
    from repro.models import init_model
    cfg = get_arch("llama3.2-1b").reduced()
    tcfg = TrainConfig(dtype="float32", remat=False, attn_chunk_q=64,
                       attn_chunk_kv=64)
    params = init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    step, opt = make_train_step(cfg, tcfg)
    opt_state = opt.init(params)
    batch = {"tokens": jnp.ones((4, 128), jnp.int32)}
    jstep = jax.jit(step)
    def run():
        p, o, m = jstep(params, opt_state, batch)
        return m["loss"]
    yield ("llama_reduced_train_b4s128", time_fn(run, iters=5, warmup=2),
           "fwd+bwd+adamw")


def summarize_dryrun():
    d = os.path.join(RESULTS_DIR, "dryrun")
    if not os.path.isdir(d):
        return
    import glob
    n_ok = n_skip = n_err = 0
    worst = (None, 0.0)
    for p in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(p))
        st = r.get("status")
        n_ok += st == "ok"
        n_skip += st == "skipped"
        n_err += st == "error"
        if st == "ok" and r["roofline"]["bound_s"] > worst[1]:
            worst = (f"{r['arch']}/{r['shape']}/{r['mesh']}",
                     r["roofline"]["bound_s"])
    yield ("dryrun_matrix", 0.0, f"ok={n_ok} skip={n_skip} err={n_err}")
    if worst[0]:
        yield ("dryrun_worst_bound", worst[1] * 1e6, worst[0])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="also run Table2 + Fig5-9 repro (CI scale)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale repro (hours)")
    add_json_arg(ap, "micro")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")
    suites = [bench_fedagg, bench_attention, bench_ssm, bench_mlstm,
              bench_control_plane, bench_cnn_step, bench_lm_step,
              summarize_dryrun]
    results, notes = {}, {}
    for suite in suites:
        try:
            for name, us, derived in suite():
                print(f"{name},{us:.1f},{derived}", flush=True)
                if suite is not summarize_dryrun:
                    results[f"{name}_us"] = us
                notes[name] = derived
        except Exception as e:  # fedlint: disable=FED007 -- bench driver reports the suite failure and moves on
            print(f"{suite.__name__},-1,ERROR:{e!r}", flush=True)
    maybe_write_json(args, "micro", results,
                     extra_context={"derived": notes})

    if args.paper or args.full:
        from benchmarks.bench_table2 import run as table2
        from benchmarks import bench_figs
        table2(ci=not args.full)
        for fn in bench_figs.ALL.values():
            fn(ci=not args.full)


if __name__ == "__main__":
    main()
