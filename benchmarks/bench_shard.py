"""A/B harness: client-sharded vs single-device cohort train+aggregate.

    XLA is told to split the host CPU into N devices BEFORE jax loads
    (--devices, default 8, appended to XLA_FLAGS via
    repro.distributed.hostdevices — an operator-exported forced count
    wins), then the SAME 16-client cohort (per-client snapshots, seeds,
    nonuniform staleness alphas) runs train_cohort + staleness merge
    through client meshes of size 1 / 2 / 8 carved from those devices.

    PYTHONPATH=src python benchmarks/bench_shard.py [--devices 8]
        [--cohort 16] [--reps 3] [--smoke]

Every arm must produce the same merged global params (the mesh-size-1
arm — the plain single-device engine — is the reference; parity is
asserted within float tolerance, nonuniform alphas and a zero-weight
straggler row included).  Per arm we report train+merge wall-clock
after a warmup rep.

Honest numbers note: forcing N host devices on a smaller physical core
count oversubscribes the CPU, so the sharded arms are NOT expected to
win wall-clock here — the harness exists to prove the distributed path
computes the same answer while the cohort's device footprint drops to
cohort/N rows per device.  (Real speedups need real devices; same
caveat as the interpret-mode Pallas kernels.)  ``--smoke`` is the
CI-gated < 30 s variant: it fails unless every sharded arm matches the
single-device reference and the largest mesh actually sharded
(mesh size > 1).
"""

from __future__ import annotations

import argparse
import sys
import time


def _early_int_flag(name: str, default: int) -> int:
    """Parse one integer flag from argv before argparse (and before jax
    locks the device count)."""
    for i, a in enumerate(sys.argv):
        if a == name and i + 1 < len(sys.argv):
            return int(sys.argv[i + 1])
        if a.startswith(name + "="):
            return int(a.split("=", 1)[1])
    return default


from repro.distributed.hostdevices import ensure_host_device_count

ensure_host_device_count(_early_int_flag("--devices", 8))

import jax                                               # noqa: E402
import numpy as np                                       # noqa: E402

from common import add_json_arg, maybe_write_json        # noqa: E402
from repro.config import get_arch                        # noqa: E402
from repro.config.base import FLConfig                   # noqa: E402
from repro.core.engine import make_engine                # noqa: E402
from repro.distributed import make_client_mesh           # noqa: E402
from repro.fl.client import CNNTrainer                   # noqa: E402


def run_arm(trainer, fl, mesh_size: int, starts, ids, seeds, alphas,
            reps: int):
    eng = make_engine(trainer, mesh=make_client_mesh(mesh_size))
    g = trainer.init_params(fl.seed)

    def once():
        stacked, _ = eng.train_cohort(starts, ids, seeds)
        merged = eng.merge_staleness(g, stacked, alphas)
        jax.block_until_ready(merged)
        return merged

    merged = once()                    # warmup rep: compile + first run
    t0 = time.perf_counter()
    for _ in range(reps):
        merged = once()
    wall = (time.perf_counter() - t0) / max(reps, 1)
    return merged, {"mesh": mesh_size, "wall_s": wall,
                    "rows_per_device": -(-len(ids) // mesh_size),
                    "engine": type(eng).__name__}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count (consumed before jax "
                         "init; an exported XLA_FLAGS forced count wins)")
    ap.add_argument("--cohort", type=int, default=16)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (< 30 s): parity gate only")
    add_json_arg(ap, "shard")
    args = ap.parse_args(argv)

    if args.smoke:
        args.cohort, args.clients, args.reps = 16, 16, 1

    n_dev = len(jax.devices())
    mesh_sizes = sorted({m for m in (1, 2, 8) if m <= n_dev} | {1})
    print(f"[bench_shard] {n_dev} host devices; arms: mesh {mesh_sizes}")

    fl = FLConfig(n_clients=args.clients, n_tiers=4, tau=4, rounds=2,
                  mu=0.0, primary_frac=0.7, seed=args.seed, lr=0.003)
    trainer = CNNTrainer(get_arch("cnn-mnist").reduced(), fl, "mnist",
                         scale=0.01)
    ids = [c % fl.n_clients for c in range(args.cohort)]
    seeds = [7 * c + 1 for c in range(args.cohort)]
    starts = [trainer.init_params(c % 3) for c in ids]
    # PR 2 staleness weights, nonuniform, with one zero-alpha straggler
    alphas = 0.6 * (np.arange(args.cohort, dtype=np.float64) + 1.0) ** -0.5
    alphas[min(3, args.cohort - 1)] = 0.0

    results, merged = {}, {}
    for m in mesh_sizes:
        merged[m], rec = run_arm(trainer, fl, m, starts, ids, seeds,
                                 alphas, args.reps)
        results[f"mesh{m}"] = rec
        print(f"[mesh={m}] {rec['engine']:>20s}  "
              f"rows/device={rec['rows_per_device']:2d}  "
              f"train+merge={rec['wall_s']:6.2f}s")

    ref = merged[1]
    max_err, parity_ok = 0.0, True
    for m in mesh_sizes[1:]:
        for a, b in zip(jax.tree_util.tree_leaves(merged[m]),
                        jax.tree_util.tree_leaves(ref)):
            err = float(np.max(np.abs(np.asarray(a, np.float32)
                                      - np.asarray(b, np.float32))))
            max_err = max(max_err, err)
            parity_ok &= err <= 1e-4
    results["max_abs_err_vs_mesh1"] = max_err
    results["parity_ok"] = parity_ok
    print(f"[bench_shard] max |sharded - single-device| = {max_err:.2e} "
          f"({'OK' if parity_ok else 'MISMATCH'})")

    maybe_write_json(args, "shard", results)
    if args.smoke:
        ok = parity_ok and max(mesh_sizes) > 1
        print(f"[bench_shard] smoke {'PASS' if ok else 'FAIL'}")
        raise SystemExit(0 if ok else 1)
    return results


if __name__ == "__main__":
    main()
