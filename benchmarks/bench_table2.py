"""Paper Table 2: best accuracy + time-to-preset-accuracy, per method.

Full paper setting: 50 clients, mu=0.1, CNN/ResNet on three datasets,
#=0.7 column (plus CIFAR non-iid sweep in bench_fig5).  ``--ci`` shrinks
everything so the table finishes in minutes on 1 CPU.
"""

from __future__ import annotations

import argparse
import json
import os


from benchmarks.common import (RESULTS_DIR, add_json_arg, maybe_write_json,
                               run_fl_experiment)

METHODS = ["fedavg", "tifl", "fedasync", "feddct"]


def run(ci: bool = True, mu: float = 0.1, primary_frac: float = 0.7,
        args=None):
    if ci:
        settings = dict(rounds=25, n_clients=20, tau=3, scale=0.02,
                        eval_every=1)
        workloads = [("cnn-mnist", 0.35), ("cnn-fmnist", 0.30)]
    else:
        settings = dict(rounds=300, n_clients=50, tau=5, scale=0.2,
                        eval_every=2)
        workloads = [("cnn-mnist", 0.90), ("cnn-fmnist", 0.75),
                     ("resnet8-cifar10", 0.55)]
    rows = []
    for arch, target in workloads:
        for method in METHODS:
            h = run_fl_experiment(arch=arch, method=method, mu=mu,
                                  primary_frac=primary_frac, **settings)
            t_target = h.time_to_accuracy(target)
            rows.append({
                "dataset": arch, "method": method,
                "best_acc": round(h.best_accuracy(smooth=3), 4),
                "time_to_target_s":
                    round(t_target, 1) if t_target else None,
                "target": target,
                "total_time_s": round(h.times[-1], 1),
            })
            print(f"[table2] {arch:16s} {method:9s} "
                  f"acc={rows[-1]['best_acc']:.4f} "
                  f"t@{target}={rows[-1]['time_to_target_s']} "
                  f"total={rows[-1]['total_time_s']}s", flush=True)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "table2.json"), "w") as f:
        json.dump(rows, f, indent=1)
    if args is not None:
        maybe_write_json(args, "table2", {"rows": rows},
                         extra_context={"ci": ci, "mu": mu,
                                        "primary_frac": primary_frac})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    add_json_arg(ap, "table2")
    a = ap.parse_args(argv)
    return run(ci=not a.full, args=a)


if __name__ == "__main__":
    main()
