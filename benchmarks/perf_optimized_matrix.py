import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Generalization check: apply the §Perf winners to every pathological
baseline row (useful < 0.3 or collective-bound) and record the optimized
roofline — shows the hillclimbed fixes aren't target-specific.

    PYTHONPATH=src python -m benchmarks.perf_optimized_matrix

Roofline one-off: writes its own results/perf/ records and stays
outside the ``BENCH_*.json`` / ``compare.py`` bench trajectory.
"""

import dataclasses
import json

from repro.config.base import TrainConfig

OPT = TrainConfig(context_parallel="auto", seq_parallel=False,
                  long_ctx_swa=True, decode_headdim_shard=False)

COMBOS = [
    # (arch, shape, tcfg) — context-parallel fixes replicated attention
    ("phi4-mini-3.8b", "train_4k", OPT),
    ("hymba-1.5b", "train_4k", OPT),
    ("hymba-1.5b", "prefill_32k", OPT),
    ("arctic-480b", "train_4k", OPT),
    ("arctic-480b", "prefill_32k", OPT),
    ("granite-20b", "train_4k",
     dataclasses.replace(OPT, parallelism="fsdp_only")),  # ZeRO-3: 20B

    # ZeRO-3 for the small archs at train
    ("llama3.2-1b", "train_4k",
     dataclasses.replace(OPT, parallelism="fsdp_only")),
    ("xlstm-350m", "train_4k",
     dataclasses.replace(OPT, parallelism="fsdp_only")),
    ("hubert-xlarge", "train_4k",
     dataclasses.replace(OPT, parallelism="fsdp_only")),
    # SWA long-context for the remaining full-attention archs
    ("nemotron-4-340b", "long_500k", OPT),
    ("phi4-mini-3.8b", "decode_32k", OPT),
]


def main():
    out_dir = "benchmarks/results/perf_opt"
    os.makedirs(out_dir, exist_ok=True)
    from repro.launch.dryrun import run_one
    for arch, shape, tcfg in COMBOS:
        tag = f"{arch}_{shape}_opt"
        path = os.path.join(out_dir, tag + ".json")
        if os.path.exists(path):
            print(f"[opt] {tag}: cached")
            continue
        try:
            rec = run_one(arch, shape, multi_pod=False, tcfg=tcfg,
                          verbose=False)
            t = rec["roofline"]
            print(f"[opt] {arch:16s} {shape:12s} dom={t['dominant']:13s} "
                  f"bound={t['bound_s']:9.4f} useful={t['useful_ratio']:.2f}",
                  flush=True)
        except Exception as e:  # fedlint: disable=FED007 -- matrix sweep records the config failure and continues
            rec = {"status": "error", "error": repr(e)}
            print(f"[opt] {tag}: ERROR {e}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
