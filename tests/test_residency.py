"""Tiered client-state residency (``TieredClientStateStore``): the
hot-device / cold-host split behind the dense store's API.

The load-bearing gate is randomized: seeded interleavings of
``gather`` / ``scatter`` / ``merge_scatter`` (kernel and non-kernel,
float-only and int-sidecar templates) over capacities {N, N/2, 1} must
stay BIT-identical to a dense store replaying the same ops — residency
is pure data movement, never arithmetic.  On top of that: LRU
eviction + write-behind accounting, prefetch pinning, the disk cold
tier's spill/persistence, and runner-level history parity for
fedasync / fedbuff / feddct_async at capacity < N.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import FLConfig
from repro.core.aggregation import staleness_merge_coefficients
from repro.core.baselines import run_fedasync, run_fedbuff
from repro.core.residency import (DiskColdTier, HostColdTier,
                                  TieredClientStateStore)
from repro.core.state import ClientStateStore
from repro.fl.testing import SyntheticCohortTrainer
from repro.runtime.async_loop import run_feddct_async

from test_state import (FakeLoopTrainer, IntLeafTrainer, _hist_equal,
                        _int_template, _net, _stack, _template,
                        _tree_equal)

N = 6


def _rand_tree(template, seed):
    """A random tree with ``template``'s structure/dtypes (int leaves
    get fresh in-range values, floats fresh normals)."""
    rng = np.random.default_rng(seed)

    def leaf(l):
        if jnp.issubdtype(l.dtype, jnp.floating):
            return jnp.asarray(
                rng.normal(size=l.shape).astype(np.float32)).astype(l.dtype)
        if l.dtype == jnp.bool_:
            return jnp.asarray(rng.integers(0, 2, size=l.shape).astype(bool))
        info = jnp.iinfo(l.dtype)
        return jnp.asarray(
            rng.integers(info.min, int(info.max) + 1, size=l.shape),
            l.dtype)

    return jax.tree_util.tree_map(leaf, template)


# ---------------------------------------------------------------------------
# the tentpole gate: randomized op interleavings, bitwise vs dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp-merge", "kernel-merge"])
@pytest.mark.parametrize("template_fn", [_template, _int_template],
                         ids=["float-tree", "int-sidecar-tree"])
@pytest.mark.parametrize("capacity", [N, N // 2, 1])
def test_random_interleaving_bit_identical_to_dense(capacity, template_fn,
                                                    use_kernel):
    tpl = template_fn(0)
    dense = ClientStateStore(tpl, N)
    tiered = TieredClientStateStore(tpl, N, capacity=capacity)
    assert tiered.rows == capacity
    assert dense.p == tiered.p and dense.pi == tiered.pi
    rng = np.random.default_rng(100 + capacity)

    for step in range(40):
        op = rng.integers(0, 4)
        if op == 0:
            # gather with duplicates (the engine's pow2 pad convention)
            ids = rng.integers(0, N, size=rng.integers(1, 7)).tolist()
            _tree_equal(dense.gather(ids), tiered.gather(ids))
        elif op == 1:
            ids = rng.choice(N, size=rng.integers(1, 4),
                             replace=False).tolist()
            t = _rand_tree(tpl, int(rng.integers(1 << 20)))
            ra = dense.scatter_params(ids, t)
            rb = tiered.scatter_params(ids, t)
            _tree_equal(jax.tree_util.tree_map(np.asarray, ra),
                        jax.tree_util.tree_map(np.asarray, rb))
        elif op == 2:
            ids = rng.choice(N, size=rng.integers(1, 3),
                             replace=False).tolist()
            flat = dense.flatten(_rand_tree(tpl, int(rng.integers(1 << 20))))
            dense.scatter(ids, flat)
            tiered.scatter(ids, flat)
        else:
            k = int(rng.integers(1, 6))
            ids = rng.choice(N, size=k, replace=False).tolist()
            stacked = dense.gather(ids)        # equal stores -> equal rows
            coef = staleness_merge_coefficients(
                rng.random(k).astype(np.float32))
            g = _rand_tree(tpl, int(rng.integers(1 << 20)))
            na, _ = dense.merge_scatter(ids, stacked, coef, g,
                                        use_kernel=use_kernel)
            nb, _ = tiered.merge_scatter(ids, tiered.gather(ids), coef, g,
                                         use_kernel=use_kernel)
            _tree_equal(na, nb)
        c = int(rng.integers(0, N))
        _tree_equal(dense.gather_one(c), tiered.gather_one(c))

    # final full-population sweep: every row identical in both layouts
    _tree_equal(dense.gather(list(range(N))),
                tiered.gather(list(range(N))))
    if capacity < N:
        assert tiered.n_promoted > 0           # residency actually moved


def test_padded_zero_coef_merge_is_exact_across_tiers():
    """The engine's repeat-last padded merge (coef 0 rows) over a
    capacity-1 store: pads and spills together must still be no-ops."""
    g = _template(10)
    trees = [_template(30 + i) for i in range(3)]
    coef = staleness_merge_coefficients([0.5, 0.25, 0.7])
    s1 = ClientStateStore(g, N)
    p1, _ = s1.merge_scatter([1, 2, 3], _stack(trees), coef, g)
    s2 = TieredClientStateStore(g, N, capacity=1)
    padded = _stack(trees + [trees[-1]])
    coef_pad = np.concatenate([coef, np.zeros(1, np.float32)])
    p2, _ = s2.merge_scatter([1, 2, 3, 3], padded, coef_pad, g)
    _tree_equal(p1, p2)
    for c in (1, 2, 3):
        _tree_equal(s2.gather_one(c), p1)
    _tree_equal(s2.gather_one(0), g)


# ---------------------------------------------------------------------------
# residency mechanics: LRU, write-behind, prefetch pinning
# ---------------------------------------------------------------------------

def test_lru_eviction_and_write_behind_only_dirty_rows():
    tpl = _template(0)
    store = TieredClientStateStore(tpl, N, capacity=2)
    store.gather([0, 1])                       # promote 0, 1 (clean)
    assert store.hot_clients == (0, 1)
    store.gather_one(0)                        # LRU touch: 1 is now oldest
    assert store.hot_clients == (1, 0)
    store.gather_one(2)                        # evicts 1 — clean, no write
    assert store.hot_clients == (0, 2)
    assert len(store.cold) == 0                # write-behind skipped
    t = _template(99)
    store.scatter_params([2], t)               # dirties 2 while hot
    store.gather([3, 4])                       # evicts 0 (clean), 2 (dirty)
    assert len(store.cold) == 1                # only the dirty row demoted
    assert store.n_demoted == 1
    _tree_equal(store.gather_one(2), t)        # …and reads back exactly


def test_prefetch_is_partial_and_respects_pins():
    tpl = _template(1)
    store = TieredClientStateStore(tpl, N, capacity=2)
    promoted = store.prefetch([3, 4, 5])       # truncated to capacity
    assert promoted == [3, 4]
    assert store.hot_clients == (3, 4)
    # every slot pinned: prefetch must stop quietly, not evict or raise
    assert store.prefetch([0, 1], keep=[3, 4]) == []
    assert store.hot_clients == (3, 4)
    # unpinned: prefetch evicts LRU as usual
    assert store.prefetch([0], keep=[4]) == [0]
    assert 0 in store.hot_clients and 3 not in store.hot_clients


def test_prefetch_is_only_a_hint_values_never_change():
    """A deliberately WRONG prefetch (staging clients the next window
    will not touch) must not change any value the store serves."""
    tpl = _int_template(2)
    dense = ClientStateStore(tpl, N)
    tiered = TieredClientStateStore(tpl, N, capacity=2)
    t = _rand_tree(tpl, 7)
    dense.scatter_params([0, 5], t)
    tiered.scatter_params([0, 5], t)
    tiered.prefetch([3, 4])                    # stale lookahead
    _tree_equal(dense.gather([0, 5, 3]), tiered.gather([0, 5, 3]))


def test_ensure_window_batches_promotion_for_looped_gathers():
    tpl = _template(3)
    store = TieredClientStateStore(tpl, N, capacity=3)
    store.ensure_window([2, 4, 2, 5])          # duplicates collapse
    assert set(store.hot_clients) == {2, 4, 5}
    promoted_before = store.n_promoted
    for c in (2, 4, 5):
        store.gather_one(c)                    # all hot: no further moves
    assert store.n_promoted == promoted_before
    store.ensure_window(list(range(N)))        # wider than hot: a no-op
    assert set(store.hot_clients) == {2, 4, 5}


# ---------------------------------------------------------------------------
# cold tiers
# ---------------------------------------------------------------------------

def test_host_cold_tier_defaults_and_broadcast():
    f0 = np.arange(4, dtype=np.float32)
    i0 = np.asarray([7], np.int32)
    cold = HostColdTier(f0, i0)
    f, i = cold.read([0, 3])                   # untouched -> template row
    np.testing.assert_array_equal(f, np.stack([f0, f0]))
    np.testing.assert_array_equal(i, np.stack([i0, i0]))
    cold.write([1, 2], f0 * 2, i0 * 2)         # 1-D broadcast form
    f, i = cold.read([1, 2, 0])
    np.testing.assert_array_equal(f[0], f0 * 2)
    np.testing.assert_array_equal(f[1], f0 * 2)
    np.testing.assert_array_equal(f[2], f0)
    assert len(cold) == 2


def test_disk_cold_tier_spills_and_persists(tmp_path):
    rng = np.random.default_rng(11)
    f0 = np.zeros(5, np.float32)
    i0 = np.zeros(2, np.int32)
    rows = {c: (rng.normal(size=5).astype(np.float32),
                rng.integers(0, 99, size=2).astype(np.int32))
            for c in range(7)}
    cold = DiskColdTier(str(tmp_path), 7, f0, i0, chunk=2, cache_chunks=2)
    for c, (f, i) in rows.items():             # > cache: chunks spill
        cold.write([c], f, i)
    cold.flush()
    assert len(list(tmp_path.glob("ckpt_*.npz"))) == 4  # ceil(7/2) chunks
    # a fresh tier over the same directory reads every row back exactly
    cold2 = DiskColdTier(str(tmp_path), 7, f0, i0, chunk=2)
    f, i = cold2.read(list(range(7)))
    for c in range(7):
        np.testing.assert_array_equal(f[c], rows[c][0])
        np.testing.assert_array_equal(i[c], rows[c][1])


def test_disk_tier_store_bit_identical_to_dense(tmp_path):
    tpl = _int_template(4)
    dense = ClientStateStore(tpl, N)
    tiered = TieredClientStateStore(tpl, N, capacity=2, cold="disk",
                                    cold_dir=str(tmp_path), chunk=2)
    assert tiered.residency == "tiered-disk"
    rng = np.random.default_rng(5)
    for step in range(12):
        ids = rng.choice(N, size=rng.integers(1, 4), replace=False).tolist()
        t = _rand_tree(tpl, step)
        dense.scatter_params(ids, t)
        tiered.scatter_params(ids, t)
        c = int(rng.integers(0, N))
        _tree_equal(dense.gather_one(c), tiered.gather_one(c))
    _tree_equal(dense.gather(list(range(N))),
                tiered.gather(list(range(N))))


# ---------------------------------------------------------------------------
# constructor contract
# ---------------------------------------------------------------------------

def test_tiered_store_rejects_bad_configs(tmp_path):
    from types import SimpleNamespace
    tpl = _template(0)
    with pytest.raises(ValueError):
        TieredClientStateStore(tpl, N, capacity=0)
    with pytest.raises(ValueError):
        TieredClientStateStore(tpl, N, capacity=2, cold="disk")  # no dir
    with pytest.raises(ValueError):
        TieredClientStateStore(tpl, N, capacity=2, cold="tape")
    with pytest.raises(ValueError):
        # tiered residency manages ONE device; sharding is the dense
        # store's mesh= job
        TieredClientStateStore(tpl, N, capacity=2,
                               mesh=SimpleNamespace(size=2))
    # capacity above N clamps to N (degenerate dense layout, still tiered API)
    s = TieredClientStateStore(tpl, 3, capacity=64)
    assert s.capacity == 3 and s.rows == 3


# ---------------------------------------------------------------------------
# runner-level history parity at capacity < N
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp-merge", "kernel-merge"])
def test_fedasync_tiered_history_identical_to_dense(use_kernel):
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=4, seed=3)
    hd = run_fedasync(SyntheticCohortTrainer(), _net(fl), fl, window=3,
                      eval_every=4, use_store=True,
                      use_kernel_agg=use_kernel)
    ht = run_fedasync(SyntheticCohortTrainer(), _net(fl), fl, window=3,
                      eval_every=4, store_capacity=3,
                      use_kernel_agg=use_kernel)
    _hist_equal(hd, ht)
    assert ht.meta["residency"] == "tiered-host"
    assert ht.meta["hot_rows"] == 3
    assert ht.meta["store_reason"] == "auto-tiered"
    assert hd.meta["residency"] == "dense"
    assert hd.meta["hot_rows"] == 8


@pytest.mark.parametrize("trainer_cls", [IntLeafTrainer,
                                         SyntheticCohortTrainer])
def test_fedbuff_capacity_one_history_identical_to_dense(trainer_cls):
    """Capacity 1 forces spill-path gathers and merges on every window
    (window=2 > hot rows) — histories still bit-identical.  The
    IntLeafTrainer variant rides the looped gather_one path with the
    int32 sidecar in play."""
    fl = FLConfig(n_clients=6, tau=2, rounds=4, seed=2)
    hd = run_fedbuff(trainer_cls(), _net(fl), fl, window=2, eval_every=8,
                     use_store=True)
    ht = run_fedbuff(trainer_cls(), _net(fl), fl, window=2, eval_every=8,
                     store_capacity=1)
    _hist_equal(hd, ht)
    assert ht.meta["hot_rows"] == 1


def test_feddct_async_tiered_history_identical_to_dense(tmp_path):
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=6, mu=0.3,
                  seed=5, beta=1.1)
    hd = run_feddct_async(SyntheticCohortTrainer(), _net(fl), fl,
                          use_store=True)
    ht = run_feddct_async(SyntheticCohortTrainer(), _net(fl), fl,
                          store_capacity=2)
    _hist_equal(hd, ht)
    assert ht.meta["residency"] == "tiered-host"
    # and the disk cold tier produces the same history again
    hk = run_feddct_async(SyntheticCohortTrainer(), _net(fl), fl,
                          store_capacity=2, store_cold_dir=str(tmp_path))
    _hist_equal(hd, hk)
    assert hk.meta["residency"] == "tiered-disk"


def test_tiered_history_identical_to_dict_reference():
    """Transitivity spot-check straight against the dict-of-pytrees
    reference (the PR 4 gate's other side)."""
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=4, seed=3)
    hdict = run_fedasync(FakeLoopTrainer(), _net(fl), fl, window=3,
                         eval_every=4, use_store=False)
    ht = run_fedasync(FakeLoopTrainer(), _net(fl), fl, window=3,
                      eval_every=4, store_capacity=2)
    _hist_equal(hdict, ht)
    assert hdict.meta["residency"] == "dict"
    assert hdict.meta["hot_rows"] == 0


def test_use_store_false_wins_over_capacity():
    """Explicit dict-reference requests beat the capacity hint — the
    A/B reference arm must stay a true dict path."""
    fl = FLConfig(n_clients=6, tau=2, rounds=2, seed=6)
    h = run_fedbuff(SyntheticCohortTrainer(), _net(fl), fl, window=2,
                    eval_every=8, use_store=False, store_capacity=2)
    assert h.meta["store_path"] == "dict"
    assert h.meta["store_reason"] == "forced-off"
