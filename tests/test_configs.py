"""Config registry + derived quantities."""

import pytest

from repro.config import get_arch, list_archs
from repro.config.base import INPUT_SHAPES

ASSIGNED = {
    "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
}

PARAM_TARGETS = {  # billions, tolerance band
    "granite-20b": (18, 23), "nemotron-4-340b": (320, 360),
    "phi4-mini-3.8b": (3.5, 5.0), "llama3.2-1b": (1.2, 1.7),
    "mixtral-8x7b": (44, 49), "hubert-xlarge": (0.8, 1.1),
    "hymba-1.5b": (1.3, 1.9), "arctic-480b": (450, 500),
    "xlstm-350m": (0.28, 0.42), "chameleon-34b": (32, 37),
}


def test_all_assigned_registered():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_exact_dims(arch):
    L, d, h, kv, ff, v = ASSIGNED[arch]
    c = get_arch(arch)
    assert (c.num_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v)


@pytest.mark.parametrize("arch", sorted(PARAM_TARGETS))
def test_param_counts_in_band(arch):
    lo, hi = PARAM_TARGETS[arch]
    n = get_arch(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_active_params_less_than_total():
    for arch in ("mixtral-8x7b", "arctic-480b"):
        c = get_arch(arch)
        assert c.active_param_count() < c.param_count()


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_invariants(arch):
    r = get_arch(arch).reduced()
    assert r.num_layers <= 2
    assert r.d_model <= 512
    assert (r.n_experts or 0) <= 4
    assert r.family == get_arch(arch).family
    assert r.n_heads % r.n_kv_heads == 0
    assert r.param_count() > 0


def test_input_shapes_exact():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_subquadratic_flags():
    assert get_arch("xlstm-350m").subquadratic
    assert get_arch("hymba-1.5b").subquadratic
    assert get_arch("mixtral-8x7b").subquadratic      # SWA
    assert not get_arch("nemotron-4-340b").subquadratic
