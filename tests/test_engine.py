"""Batched execution engine: equivalence vs the looped reference path,
fused-aggregation parity, and cohort edge cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.config.base import FLConfig
from repro.core.baselines import run_fedavg
from repro.core.engine import BatchedClientEngine, make_engine
from repro.core.scheduler import run_feddct
from repro.fl.client import CNNTrainer
from repro.fl.network import WirelessNetwork
from repro.kernels import fedagg_op, fedagg_pytree
from repro.kernels.ref import fedagg_ref


_TRAINER_CACHE = {}


def _setup(mu=0.0, rounds=3, n_clients=8, seed=0, lr=0.003):
    fl = FLConfig(n_clients=n_clients, n_tiers=4, tau=2, rounds=rounds,
                  mu=mu, primary_frac=0.7, seed=seed, lr=lr)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    # reduced CNN: same code paths, a fraction of the compile/step cost.
    # Trainers are stateless across runs (init_params re-seeds), so one
    # instance (and its warm jit caches) is shared across tests.
    key = (n_clients, seed, lr)
    if key not in _TRAINER_CACHE:
        _TRAINER_CACHE[key] = CNNTrainer(get_arch("cnn-mnist").reduced(),
                                         fl, "mnist", scale=0.01)
    return _TRAINER_CACHE[key], net, fl


class FakeTrainer:
    """Loop-only trainer (no local_train_batch): exercises the engine's
    transparent fallback."""

    class cfg:
        arch_id = "fake"

    def init_params(self, seed=0):
        return {"w": jnp.zeros(4, jnp.float32)}

    def local_train(self, params, client_id, rnd_seed):
        return {"w": params["w"] + 1.0 + client_id}, 10 + client_id


# ---------------------------------------------------------------------------
# engine mechanics
# ---------------------------------------------------------------------------

def test_engine_empty_cohort_returns_params_unchanged():
    eng = BatchedClientEngine(FakeTrainer())
    p = {"w": jnp.ones(4)}
    out = eng.train_round(p, [], rnd_seed=1)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(p["w"]))


def test_engine_all_masked_cohort_returns_params_unchanged():
    eng = BatchedClientEngine(FakeTrainer())
    p = {"w": jnp.ones(4)}
    out = eng.train_round(p, [0, 1], rnd_seed=1, weights=[0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(p["w"]))


def test_engine_fallback_matches_manual_weighted_average():
    eng = BatchedClientEngine(FakeTrainer())
    p = {"w": jnp.zeros(4)}
    out = eng.train_round(p, [1, 3], rnd_seed=0)
    # updates: 2+... w=11: 1+1+... client 1 -> 2.0, client 3 -> 4.0
    expect = (2.0 * 11 + 4.0 * 13) / 24
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full(4, expect, np.float32), rtol=1e-6)


def test_engine_zero_weight_client_is_excluded():
    eng = BatchedClientEngine(FakeTrainer())
    p = {"w": jnp.zeros(4)}
    out = eng.train_round(p, [1, 3], rnd_seed=0, weights=[11.0, 0.0])
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full(4, 2.0, np.float32), rtol=1e-6)


def test_make_engine_rejects_unknown_mode():
    with pytest.raises(ValueError):
        make_engine(FakeTrainer(), engine="warp")


def test_cohort_padding_is_invisible():
    """Padded slots (power-of-two rounding) are sliced off: a cohort of
    3 runs as 4 on device but returns exactly a 3-row stack."""
    tr, _, fl = _setup()
    eng = make_engine(tr, engine="batched")
    params = tr.init_params(0)
    stacked, sizes = eng.train_clients(params, [0, 1, 2], 1)
    lead = {l.shape[0] for l in jax.tree_util.tree_leaves(stacked)}
    assert lead == {3}
    assert sizes.shape == (3,)


# ---------------------------------------------------------------------------
# batched == looped equivalence (RunHistory trajectories)
# ---------------------------------------------------------------------------

def _assert_histories_close(ha, hb, acc_tol=5e-3):
    assert ha.rounds == hb.rounds
    np.testing.assert_allclose(ha.times, hb.times, rtol=1e-9)
    assert ha.tier == hb.tier
    assert ha.n_selected == hb.n_selected
    assert ha.n_stragglers == hb.n_stragglers
    np.testing.assert_allclose(ha.accuracy, hb.accuracy, atol=acc_tol)


def test_feddct_batched_matches_looped_history():
    tr, net, fl = _setup(mu=0.2)
    hb = run_feddct(tr, net, fl, engine="batched")
    tr2, net2, fl2 = _setup(mu=0.2)
    hl = run_feddct(tr2, net2, fl2, engine="looped")
    _assert_histories_close(hb, hl)


def test_fedavg_batched_matches_looped_history():
    tr, net, fl = _setup()
    hb = run_fedavg(tr, net, fl, engine="batched")
    tr2, net2, fl2 = _setup()
    hl = run_fedavg(tr2, net2, fl2, engine="looped")
    _assert_histories_close(hb, hl)


def test_feddct_kernel_agg_matches_reference_agg():
    # 2 rounds: the interpret-mode kernel is an emulator, keep it short
    tr, net, fl = _setup(rounds=2)
    hk = run_feddct(tr, net, fl, engine="batched", use_kernel_agg=True)
    tr2, net2, fl2 = _setup(rounds=2)
    hr = run_feddct(tr2, net2, fl2, engine="batched", use_kernel_agg=False)
    _assert_histories_close(hk, hr)


# ---------------------------------------------------------------------------
# fedagg kernel parity (interpret mode) for engine-shaped inputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p", [(3, 17), (5, 999), (2, 4097)])
def test_fedagg_odd_p_pad_path(n, p):
    rng = np.random.default_rng(p)
    u = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=n).astype(np.float32))
    out = fedagg_op(u, w, block_p=256, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fedagg_ref(u, w)),
                               rtol=1e-5, atol=1e-6)


def test_fedagg_zero_weight_rows_masked_even_nonfinite():
    u = jnp.asarray([[1.0, 2.0], [np.nan, np.inf], [3.0, 4.0]], jnp.float32)
    w = jnp.asarray([1.0, 0.0, 1.0])
    out = fedagg_op(u, w, interpret=True)
    np.testing.assert_allclose(np.asarray(out), [2.0, 3.0], rtol=1e-6)


def test_fedagg_all_zero_weights_zeros():
    u = jnp.ones((4, 9), jnp.float32)
    out = fedagg_op(u, jnp.zeros(4), interpret=True)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


def test_fedagg_pytree_mixed_dtypes_parity():
    rng = np.random.default_rng(3)
    stacked = {
        "f32": jnp.asarray(rng.normal(size=(4, 5, 3)).astype(np.float32)),
        "bf16": jnp.asarray(rng.normal(size=(4, 7)).astype(np.float32)
                            ).astype(jnp.bfloat16),
        "scalar": jnp.asarray(rng.normal(size=(4,)).astype(np.float32)),
    }
    w = jnp.asarray([1.0, 2.0, 0.0, 3.0])
    out = fedagg_pytree(stacked, w, interpret=True)
    assert out["f32"].shape == (5, 3)
    assert out["bf16"].dtype == jnp.bfloat16
    assert out["scalar"].shape == ()
    for k in stacked:
        ref = fedagg_ref(
            stacked[k].reshape(4, -1).astype(jnp.float32), w
        ).reshape(stacked[k].shape[1:])
        np.testing.assert_allclose(np.asarray(out[k], np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_fedagg_pytree_spec_cache_reused():
    from repro.kernels import ops
    stacked = {"a": jnp.ones((2, 3)), "b": jnp.ones((2, 4, 2))}
    w = jnp.ones(2)
    fedagg_pytree(stacked, w, interpret=True)
    n_before = len(ops._UNFLATTEN_SPECS)
    fedagg_pytree(stacked, w, interpret=True)
    assert len(ops._UNFLATTEN_SPECS) == n_before
