"""Device-resident flat client-state store (``ClientStateStore``):
exact mixed-dtype gather/scatter round-trips, donation safety under
repeated in-place updates, the fused merge+scatter program, the
device-side all-masked round guard, and — the acceptance gate —
bit-identical ``RunHistory`` store vs dict-of-pytrees paths for
``fedasync(window=0/K)``, ``fedbuff`` and ``feddct_async``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import FLConfig
from repro.core.aggregation import (aggregate_or_keep,
                                    staleness_merge_coefficients,
                                    staleness_weighted_merge)
from repro.core.baselines import run_fedasync, run_fedbuff
from repro.core.engine import make_engine
from repro.core.state import ClientStateStore
from repro.fl.network import WirelessNetwork
from repro.fl.testing import SyntheticCohortTrainer
from repro.kernels.ops import quantize_rows
from repro.kernels.ref import dequantize_rows_ref, quantize_rows_ref
from repro.runtime.async_loop import run_feddct_async


def _template(seed=0):
    """Mixed-dtype model pytree: 2-d f32, bf16 vector, f16 vector,
    scalar — every leaf dtype round-trips exactly through f32 rows."""
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32)
                         ).astype(jnp.bfloat16),
        "h": jnp.asarray(rng.normal(size=(3,)).astype(np.float32)
                         ).astype(jnp.float16),
        "s": jnp.float32(rng.normal()),
    }


def _tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype and x.shape == y.shape
        if jnp.issubdtype(x.dtype, jnp.floating):
            # f32 view: bf16/f16 compare exactly through it
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
        else:
            # int/bool leaves compare exactly in their own dtype (an
            # f32 view would hide precision loss above 2^24)
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# flat row <-> pytree round-trips
# ---------------------------------------------------------------------------

def test_flatten_unflatten_roundtrip_exact_mixed_dtypes():
    t = _template(1)
    store = ClientStateStore(t, 4)
    flat = store.flatten(t)
    assert flat.dtype == jnp.float32 and flat.shape == (store.p,)
    _tree_equal(store.unflatten(flat), t)


def test_store_initializes_every_row_to_template():
    t = _template(2)
    store = ClientStateStore(t, 5)
    for c in (0, 2, 4):
        _tree_equal(store.gather_one(c), t)
    stacked = store.gather([1, 3])
    for i in range(2):
        _tree_equal(jax.tree_util.tree_map(lambda l: l[i], stacked), t)


def test_store_rejects_leaves_without_exact_carrier():
    """complex leaves have no exact f32/int32 carrier; zero clients is
    a config error.  (int/bool leaves are FINE — the sidecar segment.)"""
    with pytest.raises(TypeError):
        ClientStateStore({"c": jnp.asarray([1 + 2j], jnp.complex64)}, 2)
    with pytest.raises(ValueError):
        ClientStateStore(_template(), 0)


def _int_template(seed=0):
    """Mixed float + non-float pytree: every non-float leaf dtype the
    int32 sidecar must carry exactly."""
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32)
                         ).astype(jnp.bfloat16),
        "step": jnp.int32(int(rng.integers(0, 1000))),
        "mask": jnp.asarray(rng.integers(0, 2, size=(5,)).astype(bool)),
        "i8": jnp.asarray(rng.integers(-128, 128, size=(3,)), jnp.int8),
        "u16": jnp.asarray(rng.integers(0, 2 ** 16, size=(2,)),
                           jnp.uint16),
        "u32": jnp.asarray([2 ** 31 + 5, 3], jnp.uint32),  # > int32 max
    }


def test_store_int_bool_leaves_roundtrip_exactly():
    t = _int_template(40)
    store = ClientStateStore(t, 4)
    assert store.pi > 0
    _tree_equal(store.gather_one(1), t)
    frow, irow = store.flatten(t)
    assert frow.dtype == jnp.float32 and frow.shape == (store.p,)
    assert irow.dtype == jnp.int32 and irow.shape == (store.pi,)
    _tree_equal(store.unflatten((frow, irow)), t)
    t2 = _int_template(41)
    store.scatter_params([0, 2], t2)
    _tree_equal(store.gather_one(2), t2)
    _tree_equal(store.gather_one(3), t)
    stacked = store.gather([2, 3])
    _tree_equal(jax.tree_util.tree_map(lambda l: l[0], stacked), t2)
    _tree_equal(jax.tree_util.tree_map(lambda l: l[1], stacked), t)


def test_store_int_leaf_merge_scatter_matches_dict_merge():
    """The fused merge over a mixed float/int tree must equal the dict
    path's staleness_weighted_merge bit for bit — int leaves ride the
    same cast-through-f32 merge, then land back in the sidecar."""
    g = _int_template(42)
    store = ClientStateStore(g, 6)
    stacked = _stack([_int_template(50 + i) for i in range(3)])
    alphas = [0.5, 0.0, 0.25]
    coef = staleness_merge_coefficients(alphas)
    new_params, _ = store.merge_scatter([0, 2, 4], stacked, coef, g)
    want = staleness_weighted_merge(g, stacked, alphas)
    _tree_equal(new_params, want)
    _tree_equal(store.gather_one(2), new_params)
    _tree_equal(store.gather_one(1), g)


def test_scatter_params_targets_only_given_rows():
    t0, t1 = _template(3), _template(4)
    store = ClientStateStore(t0, 6)
    row = store.scatter_params([1, 4], t1)
    assert row.shape == (store.p,)
    _tree_equal(store.gather_one(1), t1)
    _tree_equal(store.gather_one(4), t1)
    _tree_equal(store.gather_one(0), t0)
    _tree_equal(store.gather_one(5), t0)


def test_gather_duplicate_and_padded_ids():
    t0, t1 = _template(5), _template(6)
    store = ClientStateStore(t0, 4)
    store.scatter_params([2], t1)
    stacked = store.gather([2, 2, 0, 2])       # duplicates = pad slots
    row = lambda i: jax.tree_util.tree_map(lambda l: l[i], stacked)
    _tree_equal(row(0), t1)
    _tree_equal(row(1), t1)
    _tree_equal(row(2), t0)
    _tree_equal(row(3), t1)


def test_scatter_flat_row_with_duplicate_ids():
    t0, t1 = _template(7), _template(8)
    store = ClientStateStore(t0, 4)
    store.scatter([3, 3, 1], store.flatten(t1))
    _tree_equal(store.gather_one(3), t1)
    _tree_equal(store.gather_one(1), t1)
    _tree_equal(store.gather_one(0), t0)


# ---------------------------------------------------------------------------
# fused merge + scatter
# ---------------------------------------------------------------------------

def test_merge_scatter_matches_folded_merge_bitwise():
    rng = np.random.default_rng(9)
    g = _template(9)
    store = ClientStateStore(g, 8)
    stacked = _stack([_template(20 + i) for i in range(4)])
    alphas = [0.6, 0.3, 0.0, 0.45]             # one masked straggler
    coef = staleness_merge_coefficients(alphas)
    new_params, new_g = store.merge_scatter([0, 2, 5, 7], stacked, coef, g)
    want = staleness_weighted_merge(g, stacked, alphas)
    _tree_equal(new_params, want)
    # merged clients' rows now hold the new global; others untouched
    for c in (0, 2, 5, 7):
        _tree_equal(store.gather_one(c), new_params)
    _tree_equal(store.gather_one(1), g)
    np.testing.assert_array_equal(np.asarray(new_g),
                                  np.asarray(store.flatten(new_params)))


def test_merge_scatter_zero_coef_pad_rows_are_exact_noops():
    """Padded rows (repeat-last ids, coefficient 0) must not change the
    merge by a single bit — the engine's fused-window convention."""
    g = _template(10)
    alphas = [0.5, 0.25, 0.7]
    trees = [_template(30 + i) for i in range(3)]
    coef = staleness_merge_coefficients(alphas)

    s1 = ClientStateStore(g, 8)
    p1, _ = s1.merge_scatter([1, 2, 3], _stack(trees), coef, g)

    s2 = ClientStateStore(g, 8)
    padded = _stack(trees + [trees[-1]])       # engine edge padding
    coef_pad = np.concatenate([coef, np.zeros(1, np.float32)])
    p2, _ = s2.merge_scatter([1, 2, 3, 3], padded, coef_pad, g)
    _tree_equal(p1, p2)


def test_merge_scatter_masks_nonfinite_zero_coef_rows():
    g = _template(11)
    store = ClientStateStore(g, 4)
    bad = jax.tree_util.tree_map(lambda l: l * np.nan, _template(12))
    stacked = _stack([_template(13), bad])
    alphas = [0.4, 0.0]                        # nan row fully masked
    coef = staleness_merge_coefficients(alphas)
    new_params, _ = store.merge_scatter([0, 1], stacked, coef, g)
    for l in jax.tree_util.tree_leaves(new_params):
        assert np.isfinite(np.asarray(l, np.float32)).all()


def test_repeated_inplace_updates_no_use_after_donate():
    """scatter/merge_scatter donate the buffer: the store must rebind
    and keep serving gathers across many cycles (donation is active on
    accelerator backends; this exercises the rebind discipline)."""
    g = _template(14)
    store = ClientStateStore(g, 6)
    params = g
    for it in range(5):
        t = _template(40 + it)
        store.scatter_params([it % 6], t)
        stacked = _stack([t, _template(50 + it)])
        coef = staleness_merge_coefficients([0.5, 0.25])
        params, _ = store.merge_scatter([it % 6, (it + 1) % 6], stacked,
                                        coef, params)
        _tree_equal(store.gather_one(it % 6), params)
    assert store.buffer.shape == (6, store.p)


# ---------------------------------------------------------------------------
# device-side all-masked round guard
# ---------------------------------------------------------------------------

def test_aggregate_or_keep_all_masked_returns_params():
    params = {"w": jnp.asarray([1.0, 2.0], jnp.float32)}
    stacked = {"w": jnp.asarray([[9.0, 9.0], [np.nan, np.inf]],
                                jnp.float32)}
    out = aggregate_or_keep(params, stacked, np.zeros(2, np.float32))
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(params["w"]))


def test_aggregate_or_keep_matches_weighted_average_when_unmasked():
    from repro.core.aggregation import weighted_average_stacked
    rng = np.random.default_rng(15)
    params = {"w": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))}
    stacked = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))}
    w = np.asarray([1.0, 0.0, 2.0, 0.5], np.float32)
    out = aggregate_or_keep(params, stacked, w)
    want = weighted_average_stacked(stacked, w)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(want["w"]))


def test_train_round_all_masked_weights_keeps_params():
    class T:
        class cfg:
            arch_id = "t"

        def local_train(self, params, client_id, rnd_seed):
            return {"w": params["w"] + client_id + 1.0}, 10.0

    eng = make_engine(T())
    p = {"w": jnp.zeros(3, jnp.float32)}
    out = eng.train_round(p, [0, 1], 0, weights=[0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(out["w"]), np.zeros(3))
    assert eng.train_round(p, [], 0) is p      # empty cohort: host early-out


# ---------------------------------------------------------------------------
# trainers for the history-parity gates (no model-compile cost)
# ---------------------------------------------------------------------------

class FakeLoopTrainer:
    """Deterministic linear updates, looped path only (exercises the
    store's gather_one + stacked-fallback merge)."""

    class cfg:
        arch_id = "fake"

    def init_params(self, seed=0):
        return {"w": jnp.zeros(3, jnp.float32)}

    def local_train(self, params, client_id, rnd_seed):
        return {"w": params["w"] + (client_id + 1.0)}, 10.0 + client_id

    def evaluate(self, params):
        return float(np.clip(np.mean(np.asarray(params["w"])) / 100.0,
                             0.0, 1.0))


# the shared synthetic cohort trainer (mixed-dtype default tree)
# exercises the store's fused gather -> cohort train -> merge+scatter
# hot path without CNN compile cost
TinyCohortTrainer = SyntheticCohortTrainer


def _net(fl):
    return WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                           fl.mu, fl.failure_delay, fl.seed)


def _hist_equal(ha, hb):
    assert ha.rounds == hb.rounds
    assert ha.times == hb.times
    assert ha.accuracy == hb.accuracy
    assert ha.n_selected == hb.n_selected
    assert ha.n_stragglers == hb.n_stragglers


# ---------------------------------------------------------------------------
# acceptance gate: bit-identical histories, store vs dict-of-pytrees
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("trainer_cls", [FakeLoopTrainer,
                                         TinyCohortTrainer])
@pytest.mark.parametrize("window,window_secs", [(0, 0.0), (3, 0.0),
                                                (0, 25.0)])
def test_fedasync_store_history_identical_to_dict(trainer_cls, window,
                                                  window_secs):
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=4, seed=3)
    hs = run_fedasync(trainer_cls(), _net(fl), fl, window=window,
                      window_secs=window_secs, eval_every=4,
                      use_store=True)
    hd = run_fedasync(trainer_cls(), _net(fl), fl, window=window,
                      window_secs=window_secs, eval_every=4,
                      use_store=False)
    _hist_equal(hs, hd)
    if window or window_secs:
        assert hs.meta["mean_cohort"] > 1.0    # windows actually batched


@pytest.mark.parametrize("trainer_cls", [FakeLoopTrainer,
                                         TinyCohortTrainer])
def test_fedbuff_store_history_identical_to_dict(trainer_cls):
    fl = FLConfig(n_clients=6, tau=2, rounds=4, seed=2)
    hs = run_fedbuff(trainer_cls(), _net(fl), fl, window=2, eval_every=8,
                     use_store=True)
    hd = run_fedbuff(trainer_cls(), _net(fl), fl, window=2, eval_every=8,
                     use_store=False)
    _hist_equal(hs, hd)
    assert hs.meta["mean_cohort"] == 2.0


@pytest.mark.parametrize("trainer_cls", [FakeLoopTrainer,
                                         TinyCohortTrainer])
def test_feddct_async_store_history_identical_to_dict(trainer_cls):
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=6, mu=0.3,
                  seed=5, beta=1.1)
    hs = run_feddct_async(trainer_cls(), _net(fl), fl, use_store=True)
    hd = run_feddct_async(trainer_cls(), _net(fl), fl, use_store=False)
    _hist_equal(hs, hd)
    assert hs.meta["n_drains"] >= 1


def test_engine_train_window_matches_cohort_plus_merge():
    """The fused store window must reproduce the dict path's
    train_cohort + merge_staleness composition bit for bit."""
    tr = TinyCohortTrainer()
    eng = make_engine(tr)
    g = tr.init_params(0)
    starts = [tr.init_params(i + 1) for i in range(3)]
    ids, seeds = [4, 1, 6], [11, 22, 33]
    alphas = [0.5, 0.0, 0.3]

    store = ClientStateStore(g, 8)
    for c, t in zip(ids, starts):
        store.scatter_params([c], t)
    new_params, _ = eng.train_window(store, g, ids, seeds, alphas)

    eng2 = make_engine(tr)
    stacked, _ = eng2.train_cohort(starts, ids, seeds)
    want = eng2.merge_staleness(g, stacked, alphas)
    _tree_equal(new_params, want)


def test_use_store_default_is_windowed_only():
    """Tri-state default: the store engages exactly when windows can
    batch — the pure window=0 sequential loop keeps the dict path's
    free reference rebind (no per-event gather/scatter round-trip)."""
    fl = FLConfig(n_clients=6, tau=2, rounds=2, seed=6)
    h0 = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=0,
                      eval_every=8)
    assert h0.meta["store"] is False
    hw = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=2,
                      eval_every=8)
    assert hw.meta["store"] is True
    hf = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=0,
                      eval_every=8, use_store=True)   # explicit force
    assert hf.meta["store"] is True
    _hist_equal(h0, hf)                               # still identical


class IntLeafTrainer(FakeLoopTrainer):
    """Params carry a non-float leaf (a step counter): lives in the
    store's int32 sidecar segment and round-trips exactly."""

    def init_params(self, seed=0):
        return {"w": jnp.zeros(3, jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def local_train(self, params, client_id, rnd_seed):
        return {"w": params["w"] + (client_id + 1.0),
                "step": params["step"] + 1}, 10.0 + client_id


def test_int_leaf_template_runs_on_the_store_path():
    """The PR 4 TypeError fallback is gone: a non-float params template
    lives in the store (int32 sidecar) and the history still matches
    the dict reference bit for bit."""
    fl = FLConfig(n_clients=4, tau=2, rounds=2, seed=7)
    hs = run_fedbuff(IntLeafTrainer(), _net(fl), fl, window=2,
                     eval_every=8, use_store=True)
    assert hs.meta["store"] is True
    assert hs.meta["store_path"] == "store"
    hd = run_fedbuff(IntLeafTrainer(), _net(fl), fl, window=2,
                     eval_every=8, use_store=False)
    _hist_equal(hs, hd)


@pytest.mark.parametrize("trainer_cls", [IntLeafTrainer,
                                         TinyCohortTrainer])
def test_kernel_agg_runs_on_the_store_path(trainer_cls):
    """The store's fused merge dispatches the folded Pallas fedagg
    kernel (interpret-mode on CPU): use_kernel_agg + store is the
    default hot path now, bit-identical to the dict reference running
    the same kernel merge."""
    fl = FLConfig(n_clients=6, tau=2, rounds=3, seed=4)
    hk = run_fedbuff(trainer_cls(), _net(fl), fl, window=2,
                     eval_every=8, use_store=True, use_kernel_agg=True)
    assert hk.meta["store"] is True
    assert hk.meta["store_path"] == "store"
    assert hk.meta["kernel_agg"] is True
    hd = run_fedbuff(trainer_cls(), _net(fl), fl, window=2,
                     eval_every=8, use_store=False, use_kernel_agg=True)
    _hist_equal(hk, hd)
    # auto-resolution (use_store=None) now ALSO picks the store when
    # windows batch — kernel agg no longer forces the dict path
    ha = run_fedbuff(trainer_cls(), _net(fl), fl, window=2,
                     eval_every=8, use_kernel_agg=True)
    assert ha.meta["store_path"] == "store"
    assert ha.meta["store_reason"] == "auto-windowed"
    _hist_equal(ha, hd)


def test_kernel_agg_fedasync_and_feddct_async_store_parity():
    """The remaining acceptance-gate methods on the kernel + store
    combination: run_fedasync (windowed) and run_feddct_async."""
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=4, seed=3)
    hs = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=3,
                      eval_every=4, use_store=True, use_kernel_agg=True)
    hd = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=3,
                      eval_every=4, use_store=False, use_kernel_agg=True)
    _hist_equal(hs, hd)
    assert hs.meta["store_path"] == "store"

    fl2 = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=6, mu=0.3,
                   seed=5, beta=1.1)
    ha = run_feddct_async(TinyCohortTrainer(), _net(fl2), fl2,
                          use_store=True, use_kernel_agg=True)
    hb = run_feddct_async(TinyCohortTrainer(), _net(fl2), fl2,
                          use_store=False, use_kernel_agg=True)
    _hist_equal(ha, hb)
    assert ha.meta["store_path"] == "store"


def test_engine_train_window_kernel_matches_cohort_plus_kernel_merge():
    """Fused store window with kernel dispatch must reproduce the dict
    path's train_cohort + kernel merge_staleness bit for bit — padded
    rows (coef 0) included."""
    tr = TinyCohortTrainer()
    eng = make_engine(tr, use_kernel_agg=True)
    g = tr.init_params(0)
    starts = [tr.init_params(i + 1) for i in range(3)]
    ids, seeds = [4, 1, 6], [11, 22, 33]
    alphas = [0.5, 0.0, 0.3]

    store = ClientStateStore(g, 8)
    for c, t in zip(ids, starts):
        store.scatter_params([c], t)
    new_params, _ = eng.train_window(store, g, ids, seeds, alphas)

    eng2 = make_engine(tr, use_kernel_agg=True)
    stacked, _ = eng2.train_cohort(starts, ids, seeds)
    want = eng2.merge_staleness(g, stacked, alphas)
    _tree_equal(new_params, want)


def test_store_reason_records_resolved_path():
    """Observability: the auto-resolved snapshot path is recorded on
    the RunHistory meta instead of a warning, so benchmarks/tests can
    assert which path actually ran."""
    fl = FLConfig(n_clients=6, tau=2, rounds=2, seed=6)
    h0 = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=0,
                      eval_every=8)
    assert h0.meta["store_path"] == "dict"
    assert h0.meta["store_reason"] == "window0-sequential"
    hoff = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=2,
                        eval_every=8, use_store=False)
    assert hoff.meta["store_path"] == "dict"
    assert hoff.meta["store_reason"] == "forced-off"
    hw = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=2,
                      eval_every=8)
    assert hw.meta["store_path"] == "store"
    assert hw.meta["store_reason"] == "auto-windowed"
    hf = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=0,
                      eval_every=8, use_store=True)
    assert hf.meta["store_reason"] == "forced-on"


# ---------------------------------------------------------------------------
# int8 quantized rows + server-side error feedback (PR 9)
# ---------------------------------------------------------------------------

def _seg_layout(p, rng, max_segs=5):
    """Random contiguous (offset, size) segments covering [0, p)."""
    cuts = sorted(rng.choice(np.arange(1, p), size=min(max_segs - 1,
                                                       p - 1),
                             replace=False).tolist())
    bounds = [0] + cuts + [p]
    return tuple((bounds[i], bounds[i + 1] - bounds[i])
                 for i in range(len(bounds) - 1))


def test_quantize_rows_property_sweep_matches_ref():
    """Seeded sweep of the row quantizer against the numpy oracle:
    exact ops/ref parity, the half-step round-trip bound
    ``|x - dq(q(x))| <= scale/2`` per (row, segment), exact zeros, and
    exact constant segments."""
    rng = np.random.default_rng(123)
    for case in range(6):
        rows, p = int(rng.integers(1, 7)), int(rng.integers(4, 40))
        segs = _seg_layout(p, rng)
        x = rng.normal(size=(rows, p)).astype(np.float32)
        # per-segment magnitude spread: tiny to huge dynamic ranges
        for j, (off, size) in enumerate(segs):
            x[:, off:off + size] *= 10.0 ** float(rng.integers(-3, 4))
        # a constant segment (rng=0 -> exact path) and exact zeros
        off0, size0 = segs[0]
        x[:, off0:off0 + size0] = np.float32(rng.normal())
        zmask = rng.random(size=x.shape) < 0.15
        zmask[:, off0:off0 + size0] = False      # keep seg 0 constant
        x[zmask] = 0.0

        q, m = jax.jit(quantize_rows,
                       static_argnums=(1,))(jnp.asarray(x), segs)
        q, m = np.asarray(q), np.asarray(m)
        qr, mr = quantize_rows_ref(x, segs)
        np.testing.assert_array_equal(q, qr)        # exact ops/ref parity
        np.testing.assert_array_equal(m, mr)
        dq = dequantize_rows_ref(q, m, segs)

        assert q.dtype == np.int8 and m.shape == (rows, 2 * len(segs))
        for j, (off, size) in enumerate(segs):
            scale = m[:, j][:, None]                # (rows, 1)
            err = np.abs(x[:, off:off + size] - dq[:, off:off + size])
            assert (err <= scale * 0.5 * (1 + 1e-4) + 1e-12).all(), \
                f"case {case} seg {j}: round-trip bound violated"
        # exact zero preservation (0 is always on the snapped grid)
        np.testing.assert_array_equal(dq[zmask], 0.0)
        # the constant segment round-trips exactly (scale=1, zp=value)
        np.testing.assert_array_equal(dq[:, off0:off0 + size0],
                                      x[:, off0:off0 + size0])


def test_quant_store_roundtrip_matches_ref_pipeline():
    """Dense quant store: gather returns exactly what the numpy
    quantize->dequantize oracle predicts, for float AND int-sidecar
    templates (the sidecar stays lossless under quant_bits=8)."""
    for tmpl, seed in ((_template, 60), (_int_template, 61)):
        t0, t1 = tmpl(seed), tmpl(seed + 1)
        store = ClientStateStore(t0, 4, quant_bits=8)
        store.scatter_params([1], t1)
        row = store.flatten(t1)
        frow = np.asarray(row[0] if store.pi else row, np.float32)
        q, m = quantize_rows_ref(frow[None], store._fsegs)
        dq = dequantize_rows_ref(q, m, store._fsegs)[0]
        np.testing.assert_array_equal(np.asarray(store.bufs[0][1]), q[0])
        want = store.unflatten((jnp.asarray(dq), row[1])
                               if store.pi else jnp.asarray(dq))
        _tree_equal(store.gather_one(1), want)
        # int/bool leaves specifically: still bit-exact vs the input
        got = store.gather_one(1)
        for k, leaf in t1.items():
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                np.testing.assert_array_equal(np.asarray(got[k]),
                                              np.asarray(leaf))
        # untouched rows still serve the (quantized) template
        _tree_equal(store.gather_one(0), store.gather_one(3))


def test_quant_store_error_feedback_residual_and_addback():
    """EF contract: after scatter of row ``x`` the stored residual is
    exactly ``x - dq(q(x))``; the NEXT scatter quantizes ``x + ef``
    (add-back) and stores the new residual.  EF off keeps no state."""
    t0, t1 = _template(70), _template(71)
    store = ClientStateStore(t0, 4, quant_bits=8)
    assert store.error_feedback
    frow = np.asarray(store.flatten(t1), np.float32)

    store.scatter_params([2], t1)
    q1, m1 = quantize_rows_ref(frow[None], store._fsegs)
    dq1 = dequantize_rows_ref(q1, m1, store._fsegs)[0]
    ef1 = np.asarray(store.ef_residual(2))
    np.testing.assert_array_equal(ef1, frow - dq1)

    store.scatter_params([2], t1)                  # round 2: same update
    x2 = frow + ef1
    q2, m2 = quantize_rows_ref(x2[None], store._fsegs)
    dq2 = dequantize_rows_ref(q2, m2, store._fsegs)[0]
    np.testing.assert_array_equal(np.asarray(store.ef_residual(2)),
                                  x2 - dq2)
    np.testing.assert_array_equal(np.asarray(store.bufs[0][2]), q2[0])
    assert store.bytes_by_tier()["ef"] == 4 * store.p

    s2 = ClientStateStore(t0, 4, quant_bits=8, error_feedback=False)
    s2.scatter_params([1], t1)
    assert s2.ef_residual(1) is None
    np.testing.assert_array_equal(np.asarray(s2.bufs[0][1]), q1[0])
    assert s2.bytes_by_tier()["ef"] == 0


def test_quant_store_validation_and_byte_accounting():
    with pytest.raises(ValueError):
        ClientStateStore(_template(), 4, quant_bits=4)
    with pytest.raises(ValueError):                # needs a float leaf
        ClientStateStore({"step": jnp.zeros((), jnp.int32)}, 4,
                         quant_bits=8)
    t = _int_template(80)
    s8 = ClientStateStore(t, 4, quant_bits=8)
    s32 = ClientStateStore(t, 4)
    from repro.core.state import wire_bytes
    assert s8.wire_bytes_per_update == wire_bytes(t, 8)
    assert s32.wire_bytes_per_update == wire_bytes(t, 32)
    assert s8.wire_bytes_per_update < s32.wire_bytes_per_update
    # hot bytes shrink ~4x on the float segment (int sidecar unchanged)
    b8, b32 = s8.bytes_by_tier(), s32.bytes_by_tier()
    assert b8["hot"] < b32["hot"]
    assert b8["hot"] == 4 * (s8.p + 8 * len(s8._fsegs) + 4 * s8.pi)


def test_quant32_explicit_is_bit_identical_to_default_matrix():
    """``quant_bits=32`` IS the existing store path: explicit 32 must
    stay bit-identical to the default run and the dict reference."""
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=4, seed=3)
    base = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=3,
                        eval_every=4, use_store=True)
    h32 = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=3,
                       eval_every=4, use_store=True, quant_bits=32)
    hd = run_fedasync(TinyCohortTrainer(), _net(fl), fl, window=3,
                      eval_every=4, use_store=False)
    _hist_equal(base, h32)
    _hist_equal(h32, hd)
    assert h32.meta["quant_bits"] == 32

    fl2 = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=6, mu=0.3,
                   seed=5, beta=1.1)
    a = run_feddct_async(TinyCohortTrainer(), _net(fl2), fl2,
                         use_store=True)
    b = run_feddct_async(TinyCohortTrainer(), _net(fl2), fl2,
                         use_store=True, quant_bits=32)
    _hist_equal(a, b)


def test_quant8_seeded_deterministic_and_meta():
    """Quantized runs are seeded-deterministic (same seed -> identical
    history) and the meta records what ran; the run may differ from f32
    (gated convergence delta, NOT bit-identity)."""
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=6, mu=0.3,
                  seed=5, beta=1.1)
    ha = run_feddct_async(TinyCohortTrainer(), _net(fl), fl, quant_bits=8)
    hb = run_feddct_async(TinyCohortTrainer(), _net(fl), fl, quant_bits=8)
    _hist_equal(ha, hb)
    assert ha.meta["quant_bits"] == 8
    assert ha.meta["error_feedback"] is True
    assert ha.meta["store"] is True
    assert ha.meta["bytes_up"] > 0
    hf = run_feddct_async(TinyCohortTrainer(), _net(fl), fl,
                          use_store=True)
    assert ha.meta["wire_bytes_per_update"] \
        < hf.meta["wire_bytes_per_update"]
    assert ha.meta["store_bytes_hot"] < hf.meta["store_bytes_hot"]
    # quant8 cannot run without the store (the dict path has no rows)
    with pytest.raises(ValueError):
        run_feddct_async(TinyCohortTrainer(), _net(fl), fl, quant_bits=8,
                         use_store=False)


def test_error_feedback_cancels_accumulated_quantization_bias():
    """What EF buys — and what running WITHOUT it measurably costs.
    For a slowly-drifting row (drift far below the grid step),
    deterministic rounding repeats nearly the same error on every
    write, so the stored rows' accumulated error grows linearly
    without EF; with EF it telescopes to the one outstanding residual
    (``dq_t - x_t = ef_{t-1} - ef_t``), bounded by half a grid step."""
    t = _template(90)
    se = ClientStateStore(t, 2, quant_bits=8)
    sn = ClientStateStore(t, 2, quant_bits=8, error_feedback=False)
    frow0 = np.asarray(se.flatten(t), np.float32)
    errs_e = np.zeros_like(frow0)
    errs_n = np.zeros_like(frow0)
    for i in range(60):
        x = frow0 * np.float32(1.0 + i * 1e-5)
        for s, errs in ((se, errs_e), (sn, errs_n)):
            s.scatter([0], jnp.asarray(x))
            dq = dequantize_rows_ref(np.asarray(s.bufs[0][0])[None],
                                     np.asarray(s.bufs[1][0])[None],
                                     s._fsegs)[0]
            errs += dq - x
    assert 5.0 * np.abs(errs_e).mean() < np.abs(errs_n).mean()


def test_quant8_dense_tiered_host_disk_histories_identical(tmp_path):
    """Residency stays pure data movement under quantized rows: dense
    vs tiered-host vs tiered-disk at capacity < N are bit-identical,
    with identical modeled uplink."""
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=6, mu=0.3,
                  seed=5, beta=1.1)
    hd = run_feddct_async(TinyCohortTrainer(), _net(fl), fl, quant_bits=8)
    hh = run_feddct_async(TinyCohortTrainer(), _net(fl), fl, quant_bits=8,
                          store_capacity=3)
    hk = run_feddct_async(TinyCohortTrainer(), _net(fl), fl, quant_bits=8,
                          store_capacity=3, store_cold_dir=str(tmp_path))
    _hist_equal(hd, hh)
    _hist_equal(hd, hk)
    assert hd.meta["bytes_up"] == hh.meta["bytes_up"] \
        == hk.meta["bytes_up"]
    assert hh.meta["store_bytes_cold"] > 0
    assert hk.meta["store_bytes_cold"] > 0


@pytest.mark.slow
def test_fedasync_windowed_cnn_store_history_identical_to_dict():
    from repro.config import get_arch
    from repro.fl.client import CNNTrainer
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=3, mu=0.0,
                  primary_frac=0.7, seed=0, lr=0.003)
    tr = CNNTrainer(get_arch("cnn-mnist").reduced(), fl, "mnist",
                    scale=0.01)
    hs = run_fedasync(tr, _net(fl), fl, window_secs=15.0, eval_every=4,
                      use_store=True)
    hd = run_fedasync(tr, _net(fl), fl, window_secs=15.0, eval_every=4,
                      use_store=False)
    _hist_equal(hs, hd)
    assert hs.meta["mean_cohort"] > 1.0


@pytest.mark.slow
def test_feddct_async_quant8_cnn_convergence_gate():
    """The quantized-run convergence contract on a seeded CNN task:
    int8+EF tracks the f32 run within 1.0 accuracy point (best-acc
    over the run), while actually quantizing (the trajectory is NOT
    bit-identical to f32) and with EF live (EF on/off trajectories
    diverge).  The accumulated-bias cost of running WITHOUT EF is
    asserted deterministically in
    test_error_feedback_cancels_accumulated_quantization_bias —
    accuracy at test scale is too noisy to resolve it."""
    from repro.config import get_arch
    from repro.fl.client import CNNTrainer
    fl = FLConfig(n_clients=8, n_tiers=2, tau=2, rounds=40, mu=0.0,
                  primary_frac=0.7, seed=0, lr=0.003)

    def trainer():
        return CNNTrainer(get_arch("cnn-mnist").reduced(), fl, "mnist",
                          scale=0.05)

    h32 = run_feddct_async(trainer(), _net(fl), fl, use_store=True)
    h8 = run_feddct_async(trainer(), _net(fl), fl, quant_bits=8)
    h8n = run_feddct_async(trainer(), _net(fl), fl, quant_bits=8,
                           error_feedback=False)
    assert abs(max(h32.accuracy) - max(h8.accuracy)) <= 0.01 + 1e-9
    assert h8.accuracy != h32.accuracy        # quantization is active
    assert h8.accuracy != h8n.accuracy        # error feedback is live
    assert h8.meta["quant_bits"] == 8
    assert h8.meta["bytes_up"] < h32.meta["bytes_up"]
