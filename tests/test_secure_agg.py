"""Secure aggregation: masks cancel exactly; server sees only noise per
client; drops into FedDCT's survivor-set round."""

import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import weighted_average
from repro.core.secure_agg import _mask_like, mask_update, secure_aggregate


def _params(seed):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(7,)).astype(np.float32))}


def test_masks_cancel_in_aggregate():
    survivors = [0, 2, 5, 7]
    ps = {c: _params(c) for c in survivors}
    sizes = {0: 10.0, 2: 20.0, 5: 5.0, 7: 15.0}
    masked = [mask_update(ps[c], c, survivors, rnd=3, weight=sizes[c],
                          scale=50.0)   # huge masks: cancellation is exact
              for c in survivors]
    agg = secure_aggregate(masked, [sizes[c] for c in survivors])
    plain = weighted_average([ps[c] for c in survivors],
                             [sizes[c] for c in survivors])
    for k in plain:
        np.testing.assert_allclose(np.asarray(agg[k]), np.asarray(plain[k]),
                                   rtol=1e-4, atol=1e-4)


def test_individual_upload_is_masked():
    survivors = [0, 1]
    p = _params(0)
    up = mask_update(p, 0, survivors, rnd=0, weight=1.0, scale=50.0)
    # upload differs wildly from the raw update
    diff = float(jnp.max(jnp.abs(up["w"] - p["w"])))
    assert diff > 10.0


def test_dropout_changes_survivor_set_but_still_cancels():
    # client 3 straggled: the server announces survivors {0,1} only
    survivors = [0, 1]
    ps = {c: _params(c) for c in survivors}
    masked = [mask_update(ps[c], c, survivors, rnd=1, weight=1.0)
              for c in survivors]
    agg = secure_aggregate(masked, [1.0, 1.0])
    plain = weighted_average([ps[0], ps[1]], [1.0, 1.0])
    for k in plain:
        np.testing.assert_allclose(np.asarray(agg[k]), np.asarray(plain[k]),
                                   rtol=1e-5, atol=1e-5)


def test_mask_determinism():
    a = _mask_like(_params(0), seed=42)
    b = _mask_like(_params(0), seed=42)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_fedprox_runs():
    from repro.config.base import FLConfig
    from repro.core.baselines import run_fedprox
    from tests.test_scheduler import FakeTrainer, _net
    fl = FLConfig(n_clients=10, n_tiers=5, tau=2, rounds=3, seed=0)
    h = run_fedprox(FakeTrainer(), _net(fl), fl)
    assert len(h.accuracy) == 3
    assert h.method == "fedprox"
