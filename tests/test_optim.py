"""Optimizer library."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adam, adamw, clip_by_global_norm, cosine_schedule,
                         global_norm, linear_warmup_cosine, make_optimizer,
                         momentum, sgd)
from repro.optim.optimizer import apply_updates


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw"])
def test_optimizers_minimize_quadratic(name):
    opt = make_optimizer(name)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    lr = 0.1 if name != "adam" else 0.3
    for _ in range(200):
        grads = jax.tree_util.tree_map(lambda p: 2 * p, params)
        ups, state = opt.update(grads, state, params, lr)
        params = apply_updates(params, ups)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_adam_moments_are_f32_for_bf16_params():
    opt = adam()
    params = {"w": jnp.zeros((3,), jnp.bfloat16)}
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.float32


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the cap: unchanged
    g2 = {"a": jnp.full((4,), 0.01)}
    clipped2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(g2["a"]), rtol=1e-6)


def test_schedules():
    lr = cosine_schedule(1.0, 100)
    assert float(lr(0)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)
    wlr = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(wlr(0)) == 0.0
    assert float(wlr(10)) == pytest.approx(1.0)
    assert float(wlr(5)) == pytest.approx(0.5)


def test_weight_decay_pulls_to_zero():
    opt = adamw(weight_decay=0.5)
    params = {"w": jnp.asarray([1.0])}
    state = opt.init(params)
    zero_grads = {"w": jnp.asarray([0.0])}
    for _ in range(50):
        ups, state = opt.update(zero_grads, state, params, 0.1)
        params = apply_updates(params, ups)
    assert float(jnp.abs(params["w"])[0]) < 0.2
