"""Sharding rules: divisibility safety, expected placements, hints."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_arch
from repro.config.base import INPUT_SHAPES
from repro.launch.steps import abstract_params, input_specs
from repro.sharding import batch_specs, param_specs
from repro.sharding.hints import axis_size, hint, set_mesh


@pytest.fixture
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _leaves_with_specs(arch, mesh):
    params = abstract_params(get_arch(arch).reduced())
    specs = param_specs(params, mesh)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    return flat_p, flat_s


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b",
                                  "hymba-1.5b", "xlstm-350m",
                                  "arctic-480b", "hubert-xlarge"])
def test_specs_divide_shapes(arch, mesh11):
    """Every assigned axis must divide its dim for every arch (checked on
    the production mesh sizes via a fake size table)."""
    params = abstract_params(get_arch(arch))       # FULL config
    # emulate the 16x16 production mesh without 256 devices
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    specs = param_specs(params, FakeMesh())
    sizes = {"data": 16, "model": 16}

    def check(path, leaf, spec):
        for dim, ax in zip(np.shape(leaf), tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            s = int(np.prod([sizes[a] for a in axs]))
            assert dim % s == 0, f"{arch} {path}: {dim} % {s}"
    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs,
        is_leaf=lambda x: hasattr(x, "shape"))


def test_known_placements():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    params = abstract_params(get_arch("llama3.2-1b"))
    specs = param_specs(params, FakeMesh())
    assert tuple(specs["embed"]) == ("model", "data")
    # head d-dim deliberately NOT FSDP-sharded (contraction dim of the
    # loss matmul — §Perf llama v5)
    assert tuple(specs["head"]) == (None, "model")
    blk = specs["blocks"]
    assert tuple(blk["attn"]["wq"]) == (None, "data", "model")
    assert tuple(blk["attn"]["wo"]) == (None, "model", "data")
    assert tuple(blk["ln1"]) == (None, None)


def test_moe_expert_axis_placement():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    arctic = param_specs(abstract_params(get_arch("arctic-480b")),
                         FakeMesh())
    # 128 experts % 16 == 0 -> expert-parallel
    assert tuple(arctic["blocks"]["moe"]["w_up"])[1] == "model"
    mixtral = param_specs(abstract_params(get_arch("mixtral-8x7b")),
                          FakeMesh())
    # 8 experts % 16 != 0 -> expert axis unsharded
    assert tuple(mixtral["blocks"]["moe"]["w_up"])[1] is None


def test_batch_specs_divisibility():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        class devices:
            shape = (2, 16, 16)
    b = input_specs(get_arch("llama3.2-1b"), INPUT_SHAPES["train_4k"])
    specs = batch_specs(b, FakeMesh())
    assert tuple(specs["tokens"])[0] == ("pod", "data")
    b1 = input_specs(get_arch("llama3.2-1b"), INPUT_SHAPES["long_500k"])
    specs1 = batch_specs(b1, FakeMesh())
    assert tuple(specs1["tokens"]) == (None, None)   # B=1: replicate


def test_hint_noop_without_mesh():
    set_mesh(None)
    x = jnp.ones((4, 8))
    y = hint(x, "batch", "model")
    assert y is x
    assert axis_size("model") == 1


def test_hint_drops_nondivisible(mesh11):
    mesh = jax.make_mesh((1,), ("model",))
    set_mesh(mesh)
    try:
        x = jnp.ones((3, 8))
        y = hint(x, "model", None)      # size-1 axis -> dropped, no error
        assert y.shape == x.shape
    finally:
        set_mesh(None)


def test_fsdp_only_mode():
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    params = abstract_params(get_arch("llama3.2-1b"))
    specs = param_specs(params, FakeMesh(), mode="fsdp_only")
    # vocab 128256 % 256 == 0 -> combined-axis sharding on dim0
    assert tuple(specs["embed"])[0] == ("data", "model")
    blk = specs["blocks"]
    assert ("data", "model") in tuple(blk["attn"]["wq"])
    b = input_specs(get_arch("llama3.2-1b"), INPUT_SHAPES["train_4k"])
    bs = batch_specs(b, FakeMesh(), mode="fsdp_only")
    assert tuple(bs["tokens"])[0] == ("data", "model")
