"""Checkpoint round-trips."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint


def test_roundtrip_nested_tree(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "blocks": [jnp.ones((2,)), jnp.zeros((3,))]},
            "opt": {"m": {"w": jnp.full((2, 3), 0.5)},
                    "t": jnp.asarray(7, jnp.int32)}}
    save_checkpoint(str(tmp_path), 42, tree, metadata={"note": "x"})
    assert latest_step(str(tmp_path)) == 42
    out = load_checkpoint(str(tmp_path), 42, tree)
    import jax
    la = jax.tree_util.tree_leaves(tree)
    lb = jax.tree_util.tree_leaves(out)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_latest_step_picks_max(tmp_path):
    t = {"w": jnp.zeros(2)}
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, t)
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), 0, {"w": jnp.zeros((3,))})


def test_latest_step_empty(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None
