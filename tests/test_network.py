"""Wireless network model (paper §5.1)."""

import numpy as np

from repro.fl.network import WirelessNetwork


def test_determinism_across_instances():
    a = WirelessNetwork(10, (5, 10, 15, 20, 25), 2.0, 0.3, (30, 60), seed=4)
    b = WirelessNetwork(10, (5, 10, 15, 20, 25), 2.0, 0.3, (30, 60), seed=4)
    for c in range(10):
        for r in range(5):
            assert a.delay(c, r) == b.delay(c, r)


def test_groups_have_increasing_means():
    net = WirelessNetwork(50, (5, 10, 15, 20, 25), 2.0, 0.0, (30, 60), seed=0)
    means = [np.mean([net.delay(c, r) for r in range(200)])
             for c in (0, 10, 20, 30, 40)]
    assert all(b > a for a, b in zip(means, means[1:]))


def test_mu_increases_delays():
    base = WirelessNetwork(10, (5.0,), 2.0, 0.0, (30, 60), seed=1)
    fail = WirelessNetwork(10, (5.0,), 2.0, 0.5, (30, 60), seed=1)
    d0 = np.mean([base.delay(c, r) for c in range(10) for r in range(50)])
    d1 = np.mean([fail.delay(c, r) for c in range(10) for r in range(50)])
    assert d1 > d0 + 10          # ~0.5 * E[U(30,60)] = ~22.5


def test_failure_delay_bounds():
    net = WirelessNetwork(5, (1.0,), 0.01, 1.0, (30, 60), seed=2)
    for c in range(5):
        d = net.delay(c, 0)
        assert 30.0 <= d <= 62.0


def test_attempt_gives_fresh_draws():
    net = WirelessNetwork(5, (5.0,), 2.0, 0.0, (30, 60), seed=3)
    assert net.delay(0, 0, attempt=0) != net.delay(0, 0, attempt=1)
