"""launch.steps + roofline analyzer unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.config.base import INPUT_SHAPES
from repro.launch.steps import (abstract_decode_state, abstract_opt_state,
                                abstract_params, input_specs, model_flops,
                                swa_window_for)
from repro.roofline import analyze_hlo, roofline_terms
from repro.roofline.analysis import _shape_bytes, _trip_count


def test_input_specs_shapes():
    cfg = get_arch("llama3.2-1b")
    s = input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert s["tokens"].shape == (256, 4096)
    s = input_specs(cfg, INPUT_SHAPES["decode_32k"])
    assert s["tokens"].shape == (128, 1)
    audio = input_specs(get_arch("hubert-xlarge"), INPUT_SHAPES["train_4k"])
    assert audio["frames"].shape == (256, 4096, 1280)
    assert audio["labels"].shape == (256, 4096)


def test_encoder_decode_specs_raise():
    with pytest.raises(ValueError):
        input_specs(get_arch("hubert-xlarge"), INPUT_SHAPES["decode_32k"])


def test_abstract_params_no_allocation():
    p = abstract_params(get_arch("nemotron-4-340b"))
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(p))
    assert 3.2e11 < n < 3.6e11                     # 340B without allocating
    o = abstract_opt_state(get_arch("llama3.2-1b"))
    assert "m" in o and "v" in o


def test_abstract_decode_state_swa_window():
    cfg = get_arch("granite-20b")                  # full attention dense
    st = abstract_decode_state(cfg, INPUT_SHAPES["long_500k"])
    k = st["layers"]["kv"]["k"]
    assert k.shape[2] == 8192                      # SWA override window
    st2 = abstract_decode_state(cfg, INPUT_SHAPES["decode_32k"])
    assert st2["layers"]["kv"]["k"].shape[2] == 32768  # native full cache


def test_swa_window_rules():
    assert swa_window_for(get_arch("granite-20b"),
                          INPUT_SHAPES["long_500k"]) == 8192
    assert swa_window_for(get_arch("mixtral-8x7b"),
                          INPUT_SHAPES["long_500k"]) == -1  # has native SWA
    assert swa_window_for(get_arch("granite-20b"),
                          INPUT_SHAPES["train_4k"]) == -1


def test_model_flops_scaling():
    cfg = get_arch("llama3.2-1b")
    t = model_flops(cfg, INPUT_SHAPES["train_4k"])
    p = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    d = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert t > p > d
    # train ~ 3x prefill for same token count; shapes differ here but
    # decode must be tiny vs prefill
    assert d < p / 100
    moe = get_arch("mixtral-8x7b")
    assert model_flops(moe, INPUT_SHAPES["train_4k"]) < \
        6 * moe.param_count() * INPUT_SHAPES["train_4k"].tokens * 1.6


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_shape_bytes():
    assert _shape_bytes("f32[4,4]{1,0}") == 64
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(s32[], f32[8])") == 4 + 32
    assert _shape_bytes("pred[]") == 1


def test_trip_count():
    assert _trip_count(["%c = s32[] constant(17)",
                        "ROOT %lt = pred[] compare(%a, %c), direction=LT"]) == 17
    assert _trip_count(["no constants"]) == 1


def test_analyzer_on_scanned_matmul():
    def f(w, x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out.sum()
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((8, 32), jnp.float32)).compile()
    res = analyze_hlo(compiled.as_text())
    expected = 5 * 2 * 8 * 32 * 32                 # 5 trips x matmul
    assert res["dot_flops"] == pytest.approx(expected, rel=0.05)


def test_roofline_terms_dominance():
    t = roofline_terms(hlo_flops=197e12, hbm_bytes=0, collective_bytes=0,
                       chips=1)
    assert t["dominant"] == "compute_s"
    assert t["compute_s"] == pytest.approx(1.0)
    t2 = roofline_terms(hlo_flops=0, hbm_bytes=819e9, collective_bytes=1e12,
                        chips=1)
    assert t2["dominant"] == "collective_s"
