"""Bench-trajectory regression gate (benchmarks/compare.py): exit
codes, direction-aware tolerance bands, strict schema, and the
committed baselines gating against themselves."""

from __future__ import annotations

import json
import os
import sys

import pytest

BENCH_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                         "benchmarks")
sys.path.insert(0, BENCH_DIR)

import compare  # noqa: E402  (benchmarks/ is script-style, not a package)


def _payload():
    return {
        "bench": "async",
        "context": {"argv": ["--smoke", "--json"]},
        "results": {
            "sequential": {
                "wall_s": 0.10, "events": 16, "events_per_sec": 160.0,
                "events_per_sec_median": 150.0,
                "events_per_sec_samples": [140.0, 150.0, 160.0],
                "n_drains": 16, "virtual_time": 10.5,
                "store_path": "dict",
                "phases": {"phase_s": {"run": 0.1}, "counters": {}},
            },
            "speedup": 1.4,
            "histories_identical": True,
        },
    }


def _write(tmp_path, name, doc):
    p = str(tmp_path / name)
    with open(p, "w") as f:
        json.dump(doc, f)
    return p


def _run(tmp_path, base, fresh, *extra):
    bp = _write(tmp_path, "base.json", base)
    fp = _write(tmp_path, "fresh.json", fresh)
    return compare.main([bp, fp, *extra])


def test_identical_passes(tmp_path):
    assert _run(tmp_path, _payload(), _payload()) == 0


def test_throughput_regression_fails(tmp_path):
    fresh = _payload()
    fresh["results"]["sequential"]["events_per_sec_median"] /= 10.0
    assert _run(tmp_path, _payload(), fresh) == 1


def test_throughput_within_band_passes(tmp_path):
    fresh = _payload()
    # 2x worse is inside the default 2.5x band
    fresh["results"]["sequential"]["events_per_sec_median"] /= 2.0
    fresh["results"]["sequential"]["events_per_sec"] /= 2.0
    fresh["results"]["speedup"] /= 2.0
    assert _run(tmp_path, _payload(), fresh) == 0


def test_timing_regression_fails_and_improvement_passes(tmp_path):
    fresh = _payload()
    fresh["results"]["sequential"]["wall_s"] *= 3.0      # 3x slower
    assert _run(tmp_path, _payload(), fresh) == 1
    better = _payload()
    better["results"]["sequential"]["wall_s"] /= 10.0    # faster never fails
    better["results"]["sequential"]["events_per_sec"] *= 10.0
    assert _run(tmp_path, _payload(), better) == 0


def test_deterministic_drift_fails(tmp_path):
    fresh = _payload()
    fresh["results"]["sequential"]["events"] = 17        # seeded count moved
    assert _run(tmp_path, _payload(), fresh) == 1
    fresh = _payload()
    fresh["results"]["sequential"]["virtual_time"] = 11.0
    assert _run(tmp_path, _payload(), fresh) == 1


def test_bool_and_string_exact(tmp_path):
    fresh = _payload()
    fresh["results"]["histories_identical"] = False
    assert _run(tmp_path, _payload(), fresh) == 1
    fresh = _payload()
    fresh["results"]["sequential"]["store_path"] = "store"
    assert _run(tmp_path, _payload(), fresh) == 1


def test_schema_strictness(tmp_path):
    # baseline key missing from fresh -> regression
    fresh = _payload()
    del fresh["results"]["speedup"]
    assert _run(tmp_path, _payload(), fresh) == 1
    # extra fresh keys are fine (new metrics need no baseline refresh)
    fresh = _payload()
    fresh["results"]["new_metric"] = 42.0
    assert _run(tmp_path, _payload(), fresh) == 0


def test_noise_fields_are_skipped(tmp_path):
    fresh = _payload()
    fresh["results"]["sequential"]["phases"] = {"totally": "different"}
    fresh["results"]["sequential"]["events_per_sec_samples"] = [1.0]
    assert _run(tmp_path, _payload(), fresh) == 0
    # ... but a vanished phases block is still a schema regression
    fresh = _payload()
    del fresh["results"]["sequential"]["phases"]
    assert _run(tmp_path, _payload(), fresh) == 1


def test_tol_override_and_skip(tmp_path):
    fresh = _payload()
    fresh["results"]["sequential"]["events_per_sec_median"] /= 4.0
    assert _run(tmp_path, _payload(), fresh) == 1
    assert _run(tmp_path, _payload(), fresh,
                "--tol-metric", "events_per_sec_median=0.9") == 0
    assert _run(tmp_path, _payload(), fresh,
                "--skip", "events_per_sec_median") == 0


def test_usage_errors_exit_2(tmp_path):
    other = _payload()
    other["bench"] = "store"
    assert _run(tmp_path, _payload(), other) == 2       # bench mismatch
    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        f.write("{not json")
    good = _write(tmp_path, "good.json", _payload())
    assert compare.main([good, bad]) == 2
    assert compare.main([str(tmp_path / "missing.json"), good]) == 2
    notbench = _write(tmp_path, "nb.json", {"results": {}})
    assert compare.main([notbench, good]) == 2


def test_classify():
    assert compare.classify("wall_s") == "timing"
    assert compare.classify("stack_us") == "timing"
    assert compare.classify("events_per_sec") == "throughput"
    assert compare.classify("events_per_sec_median") == "throughput"
    assert compare.classify("speedup_median") == "throughput"
    assert compare.classify("rows_per_sec") == "throughput"
    assert compare.classify("events") == "exact"
    assert compare.classify("virtual_time") == "exact"
    assert compare.classify("phases") == "skip"
    assert compare.classify("events_per_sec_samples") == "skip"
    assert compare.classify("jax.compiles") == "skip"


@pytest.mark.parametrize("name", ["BENCH_async.json", "BENCH_store.json"])
def test_committed_baselines_gate_against_themselves(name):
    p = os.path.join(BENCH_DIR, name)
    assert compare.main([p, p]) == 0
