"""Telemetry subsystem (repro.obs): zero-overhead disabled path,
span/counter recording, exporters + validator, and the PR's acceptance
gates — traced runs are numerically invisible (bit-identical
histories) and a traced tiered feddct_async run produces a trace whose
spans cover >= 95% of the measured wall-clock with per-window
gather/train/merge/scatter attribution and residency counters."""

from __future__ import annotations

import json
import time

import pytest

from repro import obs
from repro.config.base import FLConfig
from repro.core import run_method
from repro.core.tiering import tiering
from repro.fl.network import WirelessNetwork
from repro.fl.testing import SyntheticCohortTrainer
from repro.obs import flstats
from repro.obs import report as obs_report
from repro.obs import telemetry as obs_tel
from repro.obs.validate import (sniff_format, validate_chrome,
                                validate_chrome_file, validate_file,
                                validate_lines)


def _net(fl):
    return WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                           fl.mu, fl.failure_delay, fl.seed)


def _fl(**kw):
    kw.setdefault("n_clients", 8)
    kw.setdefault("n_tiers", 4)
    kw.setdefault("tau", 2)
    kw.setdefault("rounds", 3)
    kw.setdefault("seed", 0)
    return FLConfig(**kw)


# ---------------------------------------------------------------------------
# core: disabled default, span recording, metrics
# ---------------------------------------------------------------------------

def test_noop_default_and_restore():
    assert obs_tel.TEL is obs_tel.NOOP
    assert not obs_tel.TEL.enabled
    with obs.tracing() as tel:
        assert obs_tel.TEL is tel
        assert tel.enabled
    assert obs_tel.TEL is obs_tel.NOOP


def test_noop_span_is_shared_and_inert():
    s1 = obs_tel.NOOP.span("a", x=1)
    s2 = obs_tel.NOOP.span("b")
    assert s1 is s2                       # no per-call allocation
    with s1:
        pass
    s1.start().set(y=2).end()             # manual API is also a no-op
    obs_tel.NOOP.inc("c")
    obs_tel.NOOP.gauge("g", 1.0)
    obs_tel.NOOP.observe("h", 1.0)
    obs_tel.NOOP.set_virtual_time(5.0)
    meta = {}
    obs_tel.NOOP.summarize_into(meta)
    assert meta == {}                     # disabled runs never touch meta


def test_disabled_overhead_under_noise_floor():
    """The disabled hot-path cost (attribute lookup + no-op span) must
    sit at sub-microsecond scale — compare against an empty loop."""
    n = 50_000

    def bare():
        t0 = time.perf_counter()
        for _ in range(n):
            pass
        return time.perf_counter() - t0

    def instrumented():
        t0 = time.perf_counter()
        for _ in range(n):
            with obs_tel.TEL.span("x"):
                pass
        return time.perf_counter() - t0

    bare_s = min(bare() for _ in range(3))
    inst_s = min(instrumented() for _ in range(3))
    per_call_us = (inst_s - bare_s) / n * 1e6
    assert per_call_us < 10.0, f"disabled span costs {per_call_us:.2f}us"


def test_span_records_wall_and_virtual_time():
    with obs.tracing() as tel:
        tel.set_virtual_time(10.0)
        with tel.span("work", rows=4):
            time.sleep(0.01)
            tel.set_virtual_time(25.0)
    (s,) = tel.spans
    assert s["name"] == "work"
    assert s["args"] == {"rows": 4}
    assert s["dur_us"] >= 10_000          # slept 10 ms of host time
    assert s["vt0"] == 10.0 and s["vt1"] == 25.0


def test_manual_span_and_metrics_summary():
    with obs.tracing() as tel:
        sp = tel.span("phase", k=1).start()
        tel.inc("hits")
        tel.inc("hits", 2)
        tel.gauge("depth", 3)
        tel.gauge("depth", 7)
        for v in (1.0, 2.0, 3.0, 4.0):
            tel.observe("cohort.size", v)
        sp.end()
        tel.inc("lookahead.hit", 3)
        tel.inc("lookahead.miss", 1)
    s = tel.summary()
    assert s["spans"]["phase"]["count"] == 1
    assert s["counters"]["hits"] == 3
    assert s["gauges"]["depth"] == 7.0
    h = s["hists"]["cohort.size"]
    assert h["count"] == 4 and h["mean"] == 2.5 and h["max"] == 4.0
    assert s["rates"]["lookahead_accuracy"] == 0.75
    meta = {}
    tel.summarize_into(meta)
    assert meta["telemetry"]["counters"]["hits"] == 3


def test_span_cap_counts_drops():
    with obs.tracing() as tel:
        old = obs_tel.MAX_SPANS
        obs_tel.MAX_SPANS = 2
        try:
            for _ in range(5):
                with tel.span("x"):
                    pass
        finally:
            obs_tel.MAX_SPANS = old
    assert len(tel.spans) == 2
    assert tel.counters["telemetry.dropped_spans"] == 3


# ---------------------------------------------------------------------------
# exporters + validator
# ---------------------------------------------------------------------------

def _tiny_trace():
    with obs.tracing() as tel:
        tel.set_virtual_time(1.0)
        with tel.span("run", method="t"):
            with tel.span("window.merge", cohort=2):
                pass
        tel.inc("drain.count")
        tel.gauge("queue.depth", 5)
        tel.observe("cohort.size", 2)
    return tel


def test_jsonl_export_validates(tmp_path):
    tel = _tiny_trace()
    p = str(tmp_path / "t.jsonl")
    assert tel.export_jsonl(p) == p
    errors, counts = validate_file(p)
    assert errors == []
    assert counts["meta"] == 1 and counts["summary"] == 1
    assert counts["span"] == 2
    with open(p) as f:
        first = json.loads(f.readline())
    assert first["type"] == "meta"
    assert first["schema_version"] == obs.SCHEMA_VERSION


def test_validator_rejects_corrupt_traces():
    errors, _ = validate_lines(["not json at all"])
    assert any("not JSON" in e for e in errors)
    meta = json.dumps({"type": "meta",
                       "schema_version": obs.SCHEMA_VERSION,
                       "clock": "perf_counter_us"})
    span = json.dumps({"type": "span", "name": "x", "ts_us": 0.0,
                       "dur_us": 1.0, "vt0": 0, "vt1": 0, "args": {}})
    summ = json.dumps({"type": "summary", "wall_s": 0.1, "spans": {},
                       "counters": {}})
    # happy path
    assert validate_lines([meta, span, summ])[0] == []
    # meta not first
    assert validate_lines([span, meta, summ])[0]
    # missing required span key
    bad = json.dumps({"type": "span", "name": "x"})
    assert any("missing" in e for e in validate_lines([meta, bad, summ])[0])
    # unknown record type
    unk = json.dumps({"type": "mystery"})
    assert any("unknown" in e for e in validate_lines([meta, span, unk,
                                                       summ])[0])
    # wrong schema version
    old = json.dumps({"type": "meta", "schema_version": 99,
                      "clock": "perf_counter_us"})
    assert any("schema_version" in e
               for e in validate_lines([old, span, summ])[0])


def test_chrome_export_shape(tmp_path):
    tel = _tiny_trace()
    p = str(tmp_path / "t.json")
    tel.export_chrome(p)
    doc = json.load(open(p))
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"run", "window.merge"}
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert "vt0" in e["args"] and "vt1" in e["args"]
    assert any(e["ph"] == "C" and e["name"] == "queue.depth"
               for e in events)
    assert doc["otherData"]["schema_version"] == obs.SCHEMA_VERSION
    assert doc["otherData"]["counters"]["drain.count"] == 1


# ---------------------------------------------------------------------------
# numerical invisibility: tracing must not change any history
# ---------------------------------------------------------------------------

CASES = [
    ("fedasync", dict(window=3, eval_every=2), None),
    ("fedbuff", dict(eval_every=2), None),
    ("feddct_async", dict(), None),
    ("feddct_async", dict(), 2),
    ("feddct", dict(), None),
    ("tifl", dict(), None),
]


@pytest.mark.parametrize("method,kw,capacity", CASES,
                         ids=["fedasync-window", "fedbuff",
                              "feddct_async-dense", "feddct_async-tiered",
                              "feddct-sync", "tifl-sync"])
def test_tracing_is_numerically_invisible(method, kw, capacity):
    """Bit-identical RunHistories with tracing on vs off; the traced
    meta differs ONLY by the additive ``telemetry`` block."""
    fl = _fl()
    if capacity is not None:
        kw = dict(kw, store_capacity=capacity)
    h_off = run_method(method, SyntheticCohortTrainer(), _net(fl), fl, **kw)
    with obs.tracing():
        h_on = run_method(method, SyntheticCohortTrainer(), _net(fl), fl,
                          **kw)
    assert h_on.times == h_off.times
    assert h_on.rounds == h_off.rounds
    assert h_on.accuracy == h_off.accuracy
    assert h_on.tier == h_off.tier
    assert h_on.n_selected == h_off.n_selected
    assert "telemetry" not in h_off.meta
    on_meta = dict(h_on.meta)
    assert on_meta.pop("telemetry") is not None
    assert on_meta == h_off.meta


def test_sync_loops_record_uniform_execution_meta():
    """Satellite: every sync loop records the resolved kernel/mesh
    facts the async runners already carry."""
    fl = _fl(rounds=2)
    for method in ("feddct", "fedavg", "tifl", "fedprox"):
        h = run_method(method, SyntheticCohortTrainer(), _net(fl), fl)
        assert h.meta["kernel_agg"] is False, method
        assert h.meta["mesh_devices"] == 1, method


def test_sync_loop_traced_summary():
    fl = _fl(rounds=2)
    with obs.tracing():
        h = run_method("feddct", SyntheticCohortTrainer(), _net(fl), fl)
    t = h.meta["telemetry"]
    assert t["spans"]["run"]["count"] == 1
    assert "round.train" in t["spans"]
    assert "round.select" in t["spans"]
    # virtual clock advanced: the run span covers simulated time
    assert t["spans"]["run"]["total_vt"] > 0


# ---------------------------------------------------------------------------
# acceptance: traced tiered feddct_async end-to-end
# ---------------------------------------------------------------------------

def test_traced_tiered_feddct_async_acceptance(tmp_path):
    """The PR acceptance gate: a tiered-residency feddct_async run
    under ``--trace`` yields (a) spans covering >= 95% of the measured
    run wall-clock, (b) per-window gather/train/merge/scatter and
    eviction attribution, (c) residency + prefetch counters, (d) a
    Chrome trace and a JSONL trace that validates."""
    fl = _fl(rounds=4)
    t0 = time.perf_counter()
    with obs.tracing() as tel:
        hist = run_method("feddct_async", SyntheticCohortTrainer(),
                          _net(fl), fl, store_capacity=4)
    wall = time.perf_counter() - t0
    t = hist.meta["telemetry"]

    # (a) coverage: the "run" span tracks the whole measured call
    run_s = t["spans"]["run"]["total_s"]
    assert run_s >= 0.95 * wall, f"run span {run_s:.4f}s < 95% of {wall:.4f}s"

    # (b) per-window phase attribution exists
    for name in ("window.prefetch", "window.merge", "window.gather",
                 "window.train", "store.merge", "store.scatter",
                 "round.select", "eval"):
        assert name in t["spans"], f"missing span {name}"

    # (c) residency + lookahead counters (capacity 4 with tau=2 windows:
    # demand staging and prefetch both fire)
    counters = t["counters"]
    assert any(k.startswith("residency.") for k in counters), counters
    assert counters.get("lookahead.hit", 0) > 0
    assert "lookahead_accuracy" in t.get("rates", {})
    assert "drain.deadline" in counters or "drain.budget" in counters

    # (d) both exporters produce valid artifacts
    jp = tel.export_jsonl(str(tmp_path / "t.jsonl"))
    errors, counts = validate_file(jp)
    assert errors == []
    assert counts["span"] == len(tel.spans)
    cp = tel.export_chrome(str(tmp_path / "t.json"))
    doc = json.load(open(cp))
    assert any(e.get("name") == "run" for e in doc["traceEvents"])


def test_prefetch_hit_rate_surfaces_when_windows_fit():
    """With a hot tier at least as wide as the windows, gathers take
    the demand-staging path and the prefetch hit rate is defined."""
    fl = _fl(n_clients=6, rounds=4)
    with obs.tracing():
        h = run_method("fedasync", SyntheticCohortTrainer(), _net(fl), fl,
                       window=2, store_capacity=4, eval_every=2)
    t = h.meta["telemetry"]
    c = t["counters"]
    demand = (c.get("residency.demand_hit", 0)
              + c.get("residency.demand_promote", 0))
    assert demand > 0, c
    assert "prefetch_hit_rate" in t["rates"]
    assert 0.0 <= t["rates"]["prefetch_hit_rate"] <= 1.0


# ---------------------------------------------------------------------------
# flstats: labeled FL-semantic streams
# ---------------------------------------------------------------------------

def test_label_roundtrip():
    assert flstats.label("fl.tier.size") == "fl.tier.size"
    name = flstats.label("fl.tier.migration", to=2, **{"from": 1})
    assert name == "fl.tier.migration{from=1,to=2}"   # sorted keys
    base, labels = flstats.parse_label(name)
    assert base == "fl.tier.migration"
    assert labels == {"from": "1", "to": "2"}
    assert flstats.parse_label("plain.counter") == ("plain.counter", {})


def test_flstats_disabled_is_inert():
    """Every record_* early-returns on the NOOP singleton (which has
    __slots__, so any state leak would raise)."""
    assert obs_tel.TEL is obs_tel.NOOP
    flstats.record_tiering([[0, 1]], thresholds=[1.0], population=2)
    flstats.record_selection([(0, 0), 1])
    flstats.record_response(1, 1.0, 2.0, timed_out=False)
    flstats.record_staleness([1, 2], [1, None])
    flstats.record_straggler("dropped", tier=1)
    flstats.record_client_updates([0, 1])
    flstats.record_update_norm(None, 0)


def test_flstats_cardinality_cap(monkeypatch):
    monkeypatch.setattr(flstats, "MAX_LABELS_PER_METRIC", 2)
    with obs.tracing() as tel:
        for t in range(5):
            flstats.record_response(t + 1, 1.0, 2.0, timed_out=False)
    admitted = [k for k in tel.hists if k.startswith("fl.response_s{")]
    assert len(admitted) == 2
    assert tel.counters[flstats.DROPPED] > 0
    # a fresh tracing block starts a fresh label budget
    with obs.tracing() as tel2:
        flstats.record_response(9, 1.0, 2.0, timed_out=False)
    assert "fl.response_s{tier=9}" in tel2.hists
    assert flstats.DROPPED not in tel2.counters


def test_flstats_migration_matrix_seeded_drift():
    """Satellite gate: a deterministic drifting-response scenario
    produces the hand-checked migration-matrix entries and per-tier
    threshold series (client 0 then client 1 slow down and sink from
    tier 1 to tier 2, displacing the fast ones upward)."""
    from repro.core.selection import tier_timeouts
    ats = [
        {0: 1.0, 1: 2.0, 2: 3.0, 3: 4.0},   # [[0,1],[2,3]]
        {0: 5.0, 1: 2.0, 2: 3.0, 3: 4.0},   # [[1,2],[3,0]]
        {0: 5.0, 1: 6.0, 2: 3.0, 3: 4.0},   # [[2,3],[0,1]]
    ]
    with obs.tracing() as tel:
        for at in ats:
            tiers = tiering(at, 2)
            flstats.record_tiering(
                tiers, thresholds=tier_timeouts(tiers, at, beta=2.0,
                                                omega=100.0),
                population=4)
    c = tel.counters
    assert c["fl.tier.migration{from=1,to=2}"] == 2
    assert c["fl.tier.migration{from=2,to=1}"] == 2
    assert c["fl.tier.rounds"] == 3
    assert tel.gauges["fl.population"] == 4.0
    # membership + threshold series: one point per round per tier
    for t in (1, 2):
        assert len(tel.gauge_series[f"fl.tier.size{{tier={t}}}"]) == 3
        assert len(tel.gauge_series[f"fl.tier.threshold_s{{tier={t}}}"]) == 3
    # Eq. 7 thresholds (beta * tier mean): hand-computed series
    assert tel.hists["fl.threshold_s{tier=1}"] == [3.0, 5.0, 7.0]
    assert tel.hists["fl.threshold_s{tier=2}"] == [7.0, 9.0, 11.0]


def test_flstats_response_and_straggler_streams():
    with obs.tracing() as tel:
        flstats.record_response(1, 3.0, 4.0, timed_out=False)
        flstats.record_response(1, 5.0, 4.0, timed_out=True)
        flstats.record_response(2, 8.0, 10.0, timed_out=False)
        flstats.record_straggler("dropped", tier=1)
        flstats.record_straggler("carried", tier=2, n=2)
        flstats.record_staleness([0, 3], [1, 2])
        flstats.record_selection([(4, 0), (5, 1), 6], population=8)
        flstats.record_client_updates([4, 5])
    c = tel.counters
    assert c["fl.tier.participate{tier=1}"] == 1
    assert c["fl.tier.timeout{tier=1}"] == 1
    assert c["fl.tier.participate{tier=2}"] == 1
    assert c["fl.straggler.dropped{tier=1}"] == 1
    assert c["fl.straggler.carried{tier=2}"] == 2
    assert c["fl.tier.selected{tier=1}"] == 1
    assert c["fl.tier.selected{tier=2}"] == 1
    assert c["fl.client.selected{client=6}"] == 1
    assert c["fl.client.update{client=4}"] == 1
    assert tel.hists["fl.response_s{tier=1}"] == [3.0, 5.0]
    assert tel.hists["fl.response_frac{tier=1}"] == [0.75, 1.25]
    assert tel.hists["fl.staleness"] == [0.0, 3.0]
    assert tel.hists["fl.staleness{tier=2}"] == [3.0]
    assert tel.gauges["fl.population"] == 8.0


# ---------------------------------------------------------------------------
# report: per-tier run report from traces / histories
# ---------------------------------------------------------------------------

def _traced_async_run(fl=None, **kw):
    fl = fl or _fl(rounds=4)
    with obs.tracing() as tel:
        hist = run_method("feddct_async", SyntheticCohortTrainer(),
                          _net(fl), fl, **kw)
    return fl, tel, hist


def test_flstats_report_acceptance_feddct_async():
    """Acceptance gate: a traced tiered feddct_async run yields a
    report with per-tier participation counts, timeout-hit rates, and
    the migration matrix, all consistent with the raw counters."""
    fl, tel, hist = _traced_async_run(store_capacity=4)
    t = hist.meta["telemetry"]
    c = t["counters"]
    rep = obs_report.build_report(t, hist.to_json())

    assert rep["rounds"] == c["fl.tier.rounds"] > 0
    assert rep["population"] == fl.n_clients
    assert rep["tiers"], "per-tier table is empty"
    for tier, row in rep["tiers"].items():
        assert row["selected"] == c.get(f"fl.tier.selected{{tier={tier}}}",
                                        0)
        seen = row["participated"] + row["timeout_hits"]
        if seen:
            assert row["timeout_hit_rate"] == pytest.approx(
                row["timeout_hits"] / seen)
        if "mean_response_s" in row:
            assert row["mean_response_s"] > 0
    total_sel = sum(r["selected"] for r in rep["tiers"].values())
    client_sel = sum(v for k, v in c.items()
                     if k.startswith("fl.client.selected{"))
    assert total_sel == client_sel > 0
    # migration matrix mirrors the labeled counters
    mig = sum(v for k, v in c.items()
              if k.startswith("fl.tier.migration{"))
    assert rep["n_migrations"] == mig
    # fairness over the whole fleet
    f = rep["fairness"]["selection"]
    assert f["population"] == fl.n_clients
    assert 0.0 <= f["gini"] <= 1.0
    assert 0.0 < f["coverage"] <= 1.0
    # staleness + cohort update norms flowed through
    assert "fl.staleness" in t["hists"]
    assert "cohort_update_norm" in rep
    # trajectory came from the history
    assert rep["trajectory"]["evals"] == len(hist.accuracy)
    # the text rendering mentions every tier row
    text = obs_report.format_report(rep, source="test")
    for tier in rep["tiers"]:
        assert f"\n{tier:>4}  " in text or str(tier) in text


def test_report_sources_agree(tmp_path):
    """The three report sources (JSONL trace, chrome trace, RunHistory
    JSON) produce the same per-tier table."""
    _, tel, hist = _traced_async_run()
    jp = str(tmp_path / "t.jsonl")
    cp = str(tmp_path / "t.json")
    hp = str(tmp_path / "h.json")
    tel.export_jsonl(jp)
    tel.export_chrome(cp)
    hist.save(hp)
    reports = []
    for p in (jp, cp, hp):
        summary, history = obs_report.load_source(p)
        assert summary is not None, p
        reports.append(obs_report.build_report(summary, history))
    assert reports[0]["tiers"] == reports[1]["tiers"] == reports[2]["tiers"]
    assert (reports[0]["migration_matrix"]
            == reports[1]["migration_matrix"]
            == reports[2]["migration_matrix"])
    # only the history source carries the trajectory
    assert "trajectory" not in reports[0]
    assert reports[2]["trajectory"]["evals"] == len(hist.accuracy)


def test_report_cli(tmp_path, capsys):
    _, tel, hist = _traced_async_run()
    jp = str(tmp_path / "t.jsonl")
    tel.export_jsonl(jp)
    out_json = str(tmp_path / "rep.json")
    assert obs_report.main([jp, "--json", out_json]) == 0
    text = capsys.readouterr().out
    assert "FL run report" in text
    rep = json.load(open(out_json))
    assert rep["tiers"]
    # an untraced input is a clean exit-2 diagnostic, not a crash
    hp = str(tmp_path / "h.json")
    hist.meta.pop("telemetry")
    hist.save(hp)
    assert obs_report.main([hp]) == 2
    bogus = str(tmp_path / "x.json")
    with open(bogus, "w") as f:
        f.write("{not json")
    assert obs_report.main([bogus]) == 2


# ---------------------------------------------------------------------------
# trace-format parity + chrome validation
# ---------------------------------------------------------------------------

def test_trace_format_parity(tmp_path):
    """Satellite gate: the end-of-run aggregate folded into
    ``RunHistory.meta["telemetry"]`` is identical to what BOTH export
    formats embed (only ``wall_s`` differs — it is stamped at export
    time)."""
    _, tel, hist = _traced_async_run()
    jp = str(tmp_path / "t.jsonl")
    cp = str(tmp_path / "t.json")
    tel.export_jsonl(jp)
    tel.export_chrome(cp)
    with open(jp) as f:
        jsonl_summary = [json.loads(l) for l in f if l.strip()][-1]
    assert jsonl_summary.pop("type") == "summary"
    chrome_summary = json.load(open(cp))["otherData"]["summary"]
    meta_summary = hist.meta["telemetry"]
    for key in ("spans", "counters", "gauges", "hists"):
        assert jsonl_summary[key] == meta_summary[key], key
        assert chrome_summary[key] == meta_summary[key], key
    assert jsonl_summary.get("rates") == meta_summary.get("rates") \
        == chrome_summary.get("rates")


def test_chrome_validator(tmp_path):
    tel = _tiny_trace()
    p = str(tmp_path / "t.json")
    tel.export_chrome(p)
    errors, counts = validate_chrome_file(p)
    assert errors == []
    assert counts["X"] == 2 and counts["M"] == 2
    assert sniff_format(p) == "chrome"
    jp = str(tmp_path / "t.jsonl")
    tel.export_jsonl(jp)
    assert sniff_format(jp) == "jsonl"


def test_chrome_validator_rejects_corrupt():
    assert validate_chrome([])[0]                       # not an object
    assert any("traceEvents" in e for e in validate_chrome({})[0])
    ok = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0,
         "dur": 1.0, "args": {"vt0": 0.0, "vt1": 0.0}}],
        "otherData": {"schema_version": obs.SCHEMA_VERSION,
                      "counters": {},
                      "summary": {"wall_s": 0.1, "spans": {},
                                  "counters": {}}}}
    assert validate_chrome(ok)[0] == []
    # X span without the virtual-time interval
    bad = json.loads(json.dumps(ok))
    bad["traceEvents"][0]["args"] = {}
    assert any("vt0" in e for e in validate_chrome(bad)[0])
    # wrong schema version
    bad = json.loads(json.dumps(ok))
    bad["otherData"]["schema_version"] = 99
    assert any("schema_version" in e for e in validate_chrome(bad)[0])
    # no spans at all
    bad = json.loads(json.dumps(ok))
    bad["traceEvents"] = []
    assert any("no spans" in e for e in validate_chrome(bad)[0])
    # summary missing required keys
    bad = json.loads(json.dumps(ok))
    bad["otherData"]["summary"] = {}
    assert any("summary missing" in e for e in validate_chrome(bad)[0])
