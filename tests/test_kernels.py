"""Pallas kernel allclose sweeps vs pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (fedagg_fold_op, fedagg_op, fedagg_partial_op,
                           gqa_flash_attention, ssm_scan_op)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (fedagg_fold_ref, fedagg_partial_ref,
                               fedagg_ref, flash_attention_ref,
                               ssm_scan_ref)

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,t,d,bq,bk", [
    (128, 128, 64, 64, 64),
    (256, 256, 32, 128, 128),
    (64, 256, 64, 64, 64),      # cross-attention shape
    (256, 128, 16, 64, 128),    # small head_dim, uneven blocks
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(s, t, d, bq, bk, causal):
    if causal and s > t:
        pytest.skip("causal with s>t undefined here")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (3, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (3, t, d), jnp.float32)
    v = jax.random.normal(ks[2], (3, t, d), jnp.float32)
    off = t - s if causal else 0
    out = flash_attention(q, k, v, causal=causal, q_offset=off,
                          block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(jnp.float32))


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **_tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (2, 128, 64)).astype(dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_gqa_wrapper_matches_model_attention():
    from repro.models.attention import naive_attention, repeat_kv
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 32), jnp.float32)
    out = gqa_flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    ref = naive_attention(q, repeat_kv(k, 8), repeat_kv(v, 8), causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d,n,chunk,bd", [
    (2, 64, 32, 8, 16, 16),
    (1, 128, 64, 16, 32, 64),
    (3, 32, 16, 4, 32, 8),
])
def test_ssm_scan_shapes(b, s, d, n, chunk, bd):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, d), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    b_in = jax.random.normal(ks[2], (b, s, n), jnp.float32)
    c_out = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    a_log = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None].repeat(d, 0)
    y = ssm_scan_op(x, dt, b_in, c_out, a_log, chunk=chunk, block_d=bd,
                    interpret=True)
    yr = ssm_scan_ref(x, dt, b_in, c_out, a_log)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_bf16():
    ks = jax.random.split(KEY, 4)
    b, s, d, n = 2, 64, 32, 8
    x = jax.random.normal(ks[0], (b, s, d)).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d))).astype(jnp.bfloat16)
    b_in = jax.random.normal(ks[2], (b, s, n)).astype(jnp.bfloat16)
    c_out = jax.random.normal(ks[3], (b, s, n)).astype(jnp.bfloat16)
    a_log = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None].repeat(d, 0)
    y = ssm_scan_op(x, dt, b_in, c_out, a_log, chunk=16, block_d=16,
                    interpret=True)
    yr = ssm_scan_ref(x, dt, b_in, c_out, a_log)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# fedagg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p,bp", [(3, 100, 64), (8, 4096, 1024),
                                    (50, 999, 256), (1, 17, 64)])
def test_fedagg_shapes(n, p, bp):
    ks = jax.random.split(KEY, 2)
    u = jax.random.normal(ks[0], (n, p), jnp.float32)
    w = jnp.abs(jax.random.normal(ks[1], (n,))) + 0.1
    out = fedagg_op(u, w, block_p=bp, interpret=True)
    ref = fedagg_ref(u, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedagg_dtypes(dtype):
    u = (jnp.arange(12.0).reshape(3, 4) / 10).astype(dtype)
    w = jnp.asarray([1.0, 1.0, 2.0])
    out = fedagg_op(u, w, block_p=4, interpret=True)
    ref = fedagg_ref(u, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# fedagg_fold (implicit global row 0) and fedagg_partial (per-shard sum)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,p,bp", [(3, 100, 64), (8, 999, 256),
                                    (1, 17, 64)])
def test_fedagg_fold_shapes(k, p, bp):
    ks = jax.random.split(KEY, 3)
    u = jax.random.normal(ks[0], (k, p), jnp.float32)
    g = jax.random.normal(ks[1], (p,), jnp.float32)
    coef = jnp.abs(jax.random.normal(ks[2], (k + 1,))) + 0.05
    out = fedagg_fold_op(u, g, coef, block_p=bp, interpret=True)
    ref = fedagg_fold_ref(u, g, coef)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_fedagg_fold_zero_coef_rows_masked_even_nonfinite():
    u = jnp.asarray([[1.0, 2.0], [np.nan, np.inf]], jnp.float32)
    g = jnp.asarray([4.0, 8.0], jnp.float32)
    coef = jnp.asarray([0.5, 0.5, 0.0], jnp.float32)
    out = fedagg_fold_op(u, g, coef, interpret=True)
    np.testing.assert_allclose(np.asarray(out), [2.5, 5.0], rtol=1e-6)


def test_fedagg_fold_padded_zero_rows_are_bitwise_noops():
    """The store's fused window pads cohorts with zero-coefficient
    rows; the kernel's masked multiply+sum must keep padded and
    unpadded windows BITWISE equal (the store-vs-dict history gate)."""
    ks = jax.random.split(KEY, 3)
    u = jax.random.normal(ks[0], (5, 403), jnp.float32)
    g = jax.random.normal(ks[1], (403,), jnp.float32)
    coef = jnp.abs(jax.random.normal(ks[2], (6,))) + 0.05
    out = fedagg_fold_op(u, g, coef, block_p=128, interpret=True)
    u_pad = jnp.concatenate([u, jnp.full((3, 403), np.nan, jnp.float32)])
    coef_pad = jnp.concatenate([coef, jnp.zeros(3, jnp.float32)])
    out_pad = fedagg_fold_op(u_pad, g, coef_pad, block_p=128,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_pad))


def test_fedagg_fold_all_zero_coef_gives_zeros():
    u = jnp.ones((3, 5), jnp.float32)
    g = jnp.ones((5,), jnp.float32)
    out = fedagg_fold_op(u, g, jnp.zeros(4), interpret=True)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=0)


@pytest.mark.parametrize("n,p,bp", [(4, 100, 64), (7, 513, 128)])
def test_fedagg_partial_shapes(n, p, bp):
    ks = jax.random.split(KEY, 2)
    u = jax.random.normal(ks[0], (n, p), jnp.float32)
    c = jnp.abs(jax.random.normal(ks[1], (n,)))
    out = fedagg_partial_op(u, c, block_p=bp, interpret=True)
    ref = fedagg_partial_ref(u, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_fedagg_partial_is_unnormalized_and_masked():
    u = jnp.asarray([[2.0, 4.0], [np.nan, np.nan], [1.0, 1.0]],
                    jnp.float32)
    c = jnp.asarray([0.5, 0.0, 2.0], jnp.float32)
    out = fedagg_partial_op(u, c, interpret=True)
    np.testing.assert_allclose(np.asarray(out), [3.0, 4.0], rtol=1e-6)
