"""CSTT (Alg. 4, Eqs. 3/4/7).  Properties run as seeded numpy sweeps."""

import numpy as np
import pytest

from repro.core.selection import (cstt, move_tier, select_from_tier,
                                  tier_timeouts)


def test_move_tier_eq3():
    assert move_tier(3, v_now=0.5, v_prev=0.4, n_tiers=5) == 2  # improved
    assert move_tier(3, v_now=0.3, v_prev=0.4, n_tiers=5) == 4  # regressed
    assert move_tier(1, 0.9, 0.1, 5) == 1                       # clamp low
    assert move_tier(5, 0.1, 0.9, 5) == 5                       # clamp high


def test_selection_favors_low_participation():
    rng = np.random.default_rng(0)
    ct = {0: 10, 1: 0, 2: 5, 3: 0, 4: 20}
    picked = select_from_tier([0, 1, 2, 3, 4], ct, tau=2, rng=rng)
    assert set(picked) == {1, 3}


@pytest.mark.parametrize("seed", range(25))
def test_selection_size_and_membership(seed):
    gen = np.random.default_rng(seed)
    n = int(gen.integers(1, 31))
    clients = gen.choice(101, size=n, replace=False).tolist()
    tau = int(gen.integers(1, 9))
    rng = np.random.default_rng(1)
    ct = {c: c % 7 for c in clients}
    picked = select_from_tier(clients, ct, tau, rng)
    assert len(picked) == min(tau, len(clients))
    assert set(picked) <= set(clients)
    if len(clients) > tau:
        # max picked ct <= min unpicked ct (lowest-ct rule)
        unpicked = set(clients) - set(picked)
        assert max(ct[c] for c in picked) <= min(ct[c] for c in unpicked)


def test_tier_timeouts_eq7():
    tiers = [[0, 1], [2]]
    at = {0: 4.0, 1: 6.0, 2: 100.0}
    d = tier_timeouts(tiers, at, beta=1.2, omega=30.0)
    assert d[0] == pytest.approx(5.0 * 1.2)
    assert d[1] == 30.0                      # capped at omega


def test_cstt_selects_from_all_tiers_up_to_t():
    rng = np.random.default_rng(0)
    tiers = [[0, 1, 2], [3, 4, 5], [6, 7, 8]]
    at = {c: float(c + 1) for c in range(9)}
    ct = {c: 0 for c in range(9)}
    # accuracy regressed -> move 1 -> 2, select from tiers 1..2
    sel, dmax, t = cstt(1, v_prev=0.5, v_now=0.4, tiers=tiers, at=at, ct=ct,
                        tau=2, beta=1.2, omega=30.0, rng=rng)
    assert t == 2
    tiers_used = {k for _, k in sel}
    assert tiers_used == {0, 1}
    assert len(sel) == 4                     # tau from each tier
    assert len(dmax) == 3


def test_gini_known_values():
    from repro.core.selection import gini

    assert gini([]) == 0.0
    assert gini([0, 0, 0]) == 0.0                 # no participation at all
    assert gini([5, 5, 5, 5]) == pytest.approx(0.0)
    assert gini([0, 0, 0, 10]) == pytest.approx(0.75)   # one winner: (n-1)/n
    assert 0.0 < gini([1, 2, 3, 4]) < 0.5
    # scale invariance
    assert gini([1, 2, 3]) == pytest.approx(gini([10, 20, 30]))


def test_participation_fairness_pads_population():
    from repro.core.selection import participation_fairness

    f = participation_fairness({0: 2, 1: 2}, population=4)
    assert f["population"] == 4
    assert f["coverage"] == pytest.approx(0.5)
    assert f["min"] == 0.0 and f["max"] == 2.0
    assert f["mean"] == pytest.approx(1.0)
    assert f["gini"] == pytest.approx(0.5)
    # unknown population: the counts dict IS the fleet
    g = participation_fairness({0: 1, 1: 1})
    assert g["population"] == 2 and g["coverage"] == 1.0
    assert g["gini"] == pytest.approx(0.0)
    # empty
    e = participation_fairness({})
    assert e == {"gini": 0.0, "coverage": 0.0, "min": 0.0, "max": 0.0,
                 "mean": 0.0, "population": 0}
