"""Dynamic tiering (Alg. 3, Eqs. 1-2) — unit + seeded-sweep properties."""

import numpy as np
import pytest

from repro.core.tiering import evaluate_client, tiering, update_avg_time
from repro.fl.network import WirelessNetwork


def test_tiering_sorted_and_partition():
    at = {0: 5.0, 1: 1.0, 2: 3.0, 3: 9.0, 4: 2.0}
    ts = tiering(at, m=2)
    assert ts == [[1, 4], [2, 0], [3]]


@pytest.mark.parametrize("seed", range(25))
def test_tiering_properties(seed):
    gen = np.random.default_rng(seed)
    n = int(gen.integers(1, 61))
    ids = gen.choice(201, size=n, replace=False)
    at = {int(c): float(t) for c, t in
          zip(ids, gen.uniform(0.01, 1e4, size=n))}
    m = int(gen.integers(1, 11))
    ts = tiering(at, m)
    flat = [c for tier in ts for c in tier]
    # exact partition of clients
    assert sorted(flat) == sorted(at)
    # tier widths: all m except possibly last
    assert all(len(t) == m for t in ts[:-1])
    assert 1 <= len(ts[-1]) <= m
    # monotone: max at of tier k <= min at of tier k+1
    for a, b in zip(ts[:-1], ts[1:]):
        assert max(at[c] for c in a) <= min(at[c] for c in b)


@pytest.mark.parametrize("seed", range(40))
def test_update_avg_time_is_running_mean(seed):
    gen = np.random.default_rng(seed)
    at = float(gen.uniform(0.01, 1e3))
    ct = int(gen.integers(0, 10_001))
    t_new = float(gen.uniform(0.01, 1e3))
    # Eq. 2 == arithmetic mean over ct+1 samples when at is mean of ct
    out = update_avg_time(at, ct, t_new)
    expected = (at * ct + t_new) / (ct + 1)
    assert out == pytest.approx(expected)
    assert min(at, t_new) - 1e-9 <= out <= max(at, t_new) + 1e-9


def test_evaluate_client_caps_wall_time_at_omega():
    net = WirelessNetwork(4, (1000.0,), 0.1, 0.0, (30, 60), seed=1)
    new_at, spent = evaluate_client(net, 0, rnd=0, kappa=3, omega=30.0)
    assert new_at > 30.0            # true average is huge
    assert spent == pytest.approx(90.0)  # but each attempt billed <= omega


def test_evaluate_deterministic():
    net = WirelessNetwork(4, (5.0, 10.0), 2.0, 0.3, (30, 60), seed=7)
    a = evaluate_client(net, 2, rnd=5, kappa=2, omega=30.0)
    b = evaluate_client(net, 2, rnd=5, kappa=2, omega=30.0)
    assert a == b


def test_migration_tracker_counts_reassignments():
    from repro.core.tiering import TierMigrationTracker, assignment

    assert assignment([[0, 1], [2, 3]]) == {0: 1, 1: 1, 2: 2, 3: 2}
    tr = TierMigrationTracker()
    assert tr.update([[0, 1], [2, 3]]) == {}    # first round has no prior
    assert tr.update([[0, 2], [1, 3]]) == {(1, 2): 1, (2, 1): 1}
    # absent clients (in flight / eval lane) keep their last tier:
    # no phantom migrations while 1 and 2 sit out
    assert tr.update([[0], [3]]) == {}
    # a returning client's move is measured from its LAST seen tier
    assert tr.update([[0, 1], [3, 2]]) == {(1, 2): 1, (2, 1): 1}
    assert tr.matrix == {(1, 2): 2, (2, 1): 2}
    assert tr.n_migrations() == 4
    assert tr.rounds == 4


@pytest.mark.parametrize("seed", range(10))
def test_migration_tracker_matches_assignment_diffs(seed):
    from repro.core.tiering import TierMigrationTracker, assignment

    gen = np.random.default_rng(seed)
    tr = TierMigrationTracker()
    prev = {}
    expected = {}
    for _ in range(8):
        at = {c: float(gen.uniform(1, 100)) for c in
              gen.choice(20, size=int(gen.integers(4, 16)),
                         replace=False)}
        tiers = tiering(at, m=3)
        cur = assignment(tiers)
        for c, t_new in cur.items():
            t_old = prev.get(c)
            if t_old is not None and t_old != t_new:
                key = (t_old, t_new)
                expected[key] = expected.get(key, 0) + 1
        prev.update(cur)
        tr.update(tiers)
    assert tr.matrix == expected
    assert tr.n_migrations() == sum(expected.values())
