"""Event-driven async runtime: deterministic event ordering, windowed
buffer draining, staleness-weighted fused aggregation, and history
equivalence of ``run_fedasync(window=0)`` vs the legacy sequential
loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.config.base import FLConfig
from repro.core.aggregation import (staleness_merge,
                                    staleness_merge_coefficients,
                                    staleness_weighted_merge)
from repro.core.baselines import (run_fedasync, run_fedasync_sequential,
                                  run_fedbuff)
from repro.core.engine import make_engine
from repro.fl.client import CNNTrainer
from repro.fl.network import WirelessNetwork
from repro.kernels import fedagg_pytree
from repro.kernels.ref import fedagg_ref
from repro.runtime import (AggregationBuffer, AsyncRunner, ClientEvent,
                           EventQueue)
from repro.runtime.async_loop import run_feddct_async


_TRAINER_CACHE = {}


def _setup(mu=0.0, rounds=2, n_clients=8, seed=0, lr=0.003):
    fl = FLConfig(n_clients=n_clients, n_tiers=4, tau=2, rounds=rounds,
                  mu=mu, primary_frac=0.7, seed=seed, lr=lr)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    key = (n_clients, seed, lr)
    if key not in _TRAINER_CACHE:
        _TRAINER_CACHE[key] = CNNTrainer(get_arch("cnn-mnist").reduced(),
                                         fl, "mnist", scale=0.01)
    return _TRAINER_CACHE[key], net, fl


# ---------------------------------------------------------------------------
# event queue
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_finish_time():
    q = EventQueue()
    for t, c in [(5.0, 1), (2.0, 4), (9.0, 0), (3.5, 2)]:
        q.push(ClientEvent(t, c))
    assert [q.pop().client for _ in range(4)] == [4, 2, 1, 0]


def test_event_queue_ties_break_on_client_id_not_insertion_order():
    for order in ([3, 1, 2, 0], [0, 1, 2, 3], [2, 0, 3, 1]):
        q = EventQueue()
        for c in order:
            q.push(ClientEvent(7.0, c, version=c, rnd=c))
        assert [q.pop().client for _ in range(4)] == [0, 1, 2, 3]


def test_event_queue_payload_does_not_affect_order():
    q = EventQueue([ClientEvent(1.0, 5, version=9, rnd=9, cost=99.0),
                    ClientEvent(1.0, 3, version=0, rnd=0, cost=0.0)])
    assert q.peek().client == 3
    assert len(q) == 2 and bool(q)


# ---------------------------------------------------------------------------
# aggregation buffer
# ---------------------------------------------------------------------------

def _queue(times):
    return EventQueue([ClientEvent(t, c) for c, t in enumerate(times)])


def test_buffer_window0_is_one_at_a_time():
    q = _queue([1.0, 2.0, 3.0])
    buf = AggregationBuffer()
    drains = []
    while q:
        drains.append([e.client for e in buf.drain(q)])
    assert drains == [[0], [1], [2]]


def test_buffer_count_window_waits_for_k():
    q = _queue([1.0, 2.0, 30.0, 40.0])
    buf = AggregationBuffer(window=3)
    assert [e.client for e in buf.drain(q)] == [0, 1, 2]
    assert [e.client for e in buf.drain(q)] == [3]


def test_buffer_time_window_anchors_on_earliest():
    q = _queue([1.0, 5.0, 6.9, 20.0])
    buf = AggregationBuffer(window_secs=6.0)
    assert [e.client for e in buf.drain(q)] == [0, 1, 2]   # <= 1.0 + 6
    assert [e.client for e in buf.drain(q)] == [3]


def test_buffer_limit_caps_the_drain():
    q = _queue([1.0, 1.1, 1.2, 1.3])
    buf = AggregationBuffer(window_secs=10.0)
    assert len(buf.drain(q, limit=2)) == 2
    assert len(buf.drain(q, limit=10)) == 2


def test_buffer_drain_until_external_deadline():
    q = _queue([1.0, 2.0, 3.0, 9.0])
    got = AggregationBuffer.drain_until(q, deadline=3.0)
    assert [e.client for e in got] == [0, 1, 2]
    assert AggregationBuffer.drain_until(q, deadline=3.0) == []
    assert len(q) == 1


def test_buffer_rejects_negative_windows():
    with pytest.raises(ValueError):
        AggregationBuffer(window=-1)


def test_buffer_close_time_semantics():
    # time-closed window: the server must wait out the full deadline
    # (it cannot know nothing else is coming) -> anchor + window_secs
    q = _queue([1.0, 3.0, 20.0])
    buf = AggregationBuffer(window_secs=6.0)
    batch = buf.drain(q)
    assert buf.close_time(batch) == 1.0 + 6.0
    # count-closed window (K-th arrival lands): closes at last arrival
    q = _queue([1.0, 3.0, 4.0, 20.0])
    buf = AggregationBuffer(window=3, window_secs=50.0)
    batch = buf.drain(q)
    assert len(batch) == 3 and buf.close_time(batch) == 4.0
    # sequential (window=0): closes at the event itself
    q = _queue([2.5])
    buf = AggregationBuffer()
    batch = buf.drain(q)
    assert buf.close_time(batch) == 2.5


# ---------------------------------------------------------------------------
# lookahead peeks (the residency prefetcher's contract)
# ---------------------------------------------------------------------------

def test_peek_n_matches_pop_order_and_never_perturbs():
    times = [5.0, 2.0, 9.0, 2.0, 7.0, 2.0]     # triple tie at 2.0
    q = _queue(times)
    snap = sorted((e.finish, e.client) for e in q._heap)
    for k in (0, -3, 1, 3, len(times), len(times) + 5):
        got = q.peek_n(k)
        assert len(got) == max(0, min(k, len(times)))
        assert sorted((e.finish, e.client) for e in q._heap) == snap
    # the peeked prefix IS the next-k pops, ties broken on client id
    want = [q.pop() for _ in range(4)]
    q2 = _queue(times)
    assert q2.peek_n(4) == want
    assert [e.client for e in q2.peek_n(4)][:3] == [1, 3, 5]


@pytest.mark.parametrize("window,window_secs,limit", [
    (0, 0.0, None), (3, 0.0, None), (0, 6.0, None), (2, 6.0, None),
    (3, 0.0, 2), (0, 50.0, 2)])
def test_peek_window_equals_the_coming_drain(window, window_secs, limit):
    times = [1.0, 5.0, 6.9, 1.0, 20.0, 6.9]
    buf = AggregationBuffer(window, window_secs)
    peeked = buf.peek_window(_queue(times), limit=limit)
    drained = buf.drain(_queue(times), limit=limit)
    assert peeked == drained
    # and peeking really popped nothing
    q = _queue(times)
    buf.peek_window(q, limit=limit)
    assert len(q) == len(times)


def test_peek_window_and_drain_empty_queue():
    buf = AggregationBuffer(window=3)
    q = EventQueue()
    assert buf.peek_window(q) == []
    assert buf.drain(q) == []


def test_drain_tied_finish_times_pop_in_client_order():
    q = _queue([4.0, 4.0, 4.0, 4.0])
    buf = AggregationBuffer(window=4)
    assert [e.client for e in buf.drain(q)] == [0, 1, 2, 3]


def test_drain_until_exact_window_boundary_is_inclusive():
    # finish == deadline drains; the next event (one ulp later) stays
    q = _queue([1.0, 3.0, np.nextafter(3.0, 4.0), 5.0])
    got = AggregationBuffer.drain_until(q, deadline=3.0)
    assert [e.client for e in got] == [0, 1]
    assert len(q) == 2
    # empty drain at a deadline before every completion
    assert AggregationBuffer.drain_until(q, deadline=0.5) == []
    assert len(q) == 2


def test_time_window_exact_boundary_is_inclusive():
    # anchor 1.0 + window 6.0: an event AT 7.0 joins the window
    q = _queue([1.0, 7.0, np.nextafter(7.0, 8.0)])
    buf = AggregationBuffer(window_secs=6.0)
    assert [e.client for e in buf.drain(q)] == [0, 1]
    assert buf.peek_window(_queue([1.0, 7.0, 8.0])) == \
        AggregationBuffer(window_secs=6.0).drain(_queue([1.0, 7.0, 8.0]))


def test_peek_until_matches_drain_until_without_popping():
    times = [1.0, 2.0, 3.0, 9.0]
    for deadline in (0.0, 2.0, 3.0, 100.0):
        for limit in (None, 2):
            q = _queue(times)
            peeked = AggregationBuffer.peek_until(q, deadline, limit=limit)
            assert len(q) == len(times)
            drained = AggregationBuffer.drain_until(q, deadline,
                                                    limit=limit)
            assert peeked == drained
    assert AggregationBuffer.peek_until(EventQueue(), 5.0) == []


# ---------------------------------------------------------------------------
# vectorized wireless delays
# ---------------------------------------------------------------------------

def test_delays_bitwise_equal_scalar_path():
    net = WirelessNetwork(20, (5, 10, 15, 20, 25), 2.0, 0.3, (30, 60),
                          seed=11)
    for rnd in (0, 7, 12345):
        got = net.delays(np.arange(20), rnd)
        want = np.asarray([net.delay(c, rnd) for c in range(20)])
        assert np.array_equal(got, want)


def test_delays_broadcasts_round_and_attempt_arrays():
    net = WirelessNetwork(6, (5.0, 9.0), 2.0, 0.2, (30, 60), seed=3)
    got = net.delays([4] * 5, 2, attempt=np.arange(5) + 1)
    want = np.asarray([net.delay(4, 2, attempt=a + 1) for a in range(5)])
    assert np.array_equal(got, want)
    got = net.delays([0, 1, 2], np.array([5, 6, 7]))
    want = np.asarray([net.delay(c, r) for c, r in zip([0, 1, 2],
                                                       [5, 6, 7])])
    assert np.array_equal(got, want)


def test_delays_respects_scalar_override_in_subclasses():
    class SpikeNet(WirelessNetwork):
        def delay(self, client, rnd, attempt=0):
            if client == 1:
                return 1e6
            return super().delay(client, rnd, attempt)

    net = SpikeNet(4, (5.0,), 2.0, 0.0, (30, 60), seed=0)
    got = net.delays([0, 1, 2, 3], 5)
    assert got[1] == 1e6
    base = WirelessNetwork(4, (5.0,), 2.0, 0.0, (30, 60), seed=0)
    assert np.array_equal(np.delete(got, 1),
                          np.delete(base.delays([0, 1, 2, 3], 5), 1))


def test_delays_empty_cohort():
    net = WirelessNetwork(4, (5.0,), 2.0, 0.0, (30, 60), seed=0)
    assert net.delays([], 0).shape == (0,)


def test_delays_negative_seed_falls_back_to_exact_path():
    # a negative base seed makes some per-element seeds negative, where
    # int64->uint64 wrapping would diverge from the scalar mod-2**63
    # path; the lo-bound guard must route those through delay()
    net = WirelessNetwork(200, (5.0, 9.0), 2.0, 0.2, (30, 60), seed=-1)
    got = net.delays(np.arange(200), 0)
    want = np.asarray([net.delay(c, 0) for c in range(200)])
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# staleness-weighted fused aggregation
# ---------------------------------------------------------------------------

def _rand_tree(rng, n):
    return {"w": jnp.asarray(rng.normal(size=(n, 4, 3)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32)
                             ).astype(jnp.bfloat16)}


def _row(tree, i):
    return jax.tree_util.tree_map(lambda l: l[i], tree)


@pytest.mark.parametrize("alphas", [
    [0.6], [0.5, 0.25], [0.9, 0.0, 0.3], [0.2, 1.0, 0.4], [0.0, 0.0]])
def test_staleness_weighted_merge_matches_sequential_fold(alphas):
    rng = np.random.default_rng(len(alphas))
    n = len(alphas)
    g = _row(_rand_tree(rng, 1), 0)
    stacked = _rand_tree(rng, n)
    want = g
    for i, a in enumerate(alphas):
        want = staleness_merge(want, _row(stacked, i), a)
    got = staleness_weighted_merge(g, stacked, alphas)
    for k in g:
        np.testing.assert_allclose(
            np.asarray(got[k], np.float32), np.asarray(want[k], np.float32),
            rtol=2e-2 if g[k].dtype == jnp.bfloat16 else 1e-5,
            atol=2e-2 if g[k].dtype == jnp.bfloat16 else 1e-6)


def test_staleness_merge_coefficients_are_convex():
    for alphas in ([0.6], [0.5, 0.25, 0.1], [1.0, 0.5], [0.0, 0.0]):
        coef = staleness_merge_coefficients(alphas)
        assert coef.shape == (len(alphas) + 1,)
        np.testing.assert_allclose(coef.sum(), 1.0, rtol=1e-6)
        assert (coef >= 0).all()


def test_staleness_weighted_merge_kernel_path_matches_jnp():
    rng = np.random.default_rng(0)
    g = _row(_rand_tree(rng, 1), 0)
    stacked = _rand_tree(rng, 3)
    alphas = [0.7, 0.0, 0.4]
    a = staleness_weighted_merge(g, stacked, alphas, use_kernel=False)
    b = staleness_weighted_merge(g, stacked, alphas, use_kernel=True,
                                 interpret=True)
    for k in g:
        np.testing.assert_allclose(np.asarray(a[k], np.float32),
                                   np.asarray(b[k], np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_fedagg_alpha_vector_kernel_matches_ref():
    rng = np.random.default_rng(5)
    u = jnp.asarray(rng.normal(size=(5, 403)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=5).astype(np.float32))
    a = jnp.asarray([1.0, 0.3, 0.0, 2.0, 0.7], jnp.float32)
    from repro.kernels import fedagg_op
    got = fedagg_op(u, w, alphas=a, block_p=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(fedagg_ref(u, w, a)),
                               rtol=1e-5, atol=1e-6)


def test_fedagg_zero_alpha_rows_masked_even_nonfinite():
    stacked = {"w": jnp.asarray([[1.0, 2.0], [np.nan, np.inf], [3.0, 4.0]],
                                jnp.float32)}
    w = jnp.ones(3)
    a = jnp.asarray([1.0, 0.0, 1.0])
    out = fedagg_pytree(stacked, w, alphas=a, interpret=True)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 3.0], rtol=1e-6)
    ref = fedagg_ref(stacked["w"], w, a)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# fake trainer: runtime mechanics without jit-compile cost
# ---------------------------------------------------------------------------

class FakeAsyncTrainer:
    """Deterministic linear updates; supports the looped cohort
    fallback (no local_train_batch/local_train_cohort)."""

    class cfg:
        arch_id = "fake"

    def init_params(self, seed=0):
        return {"w": jnp.zeros(3, jnp.float32)}

    def local_train(self, params, client_id, rnd_seed):
        return {"w": params["w"] + (client_id + 1.0)}, 10.0 + client_id

    def evaluate(self, params):
        return float(np.clip(np.mean(np.asarray(params["w"])) / 100.0,
                             0.0, 1.0))


def test_async_runner_window0_budget_and_terminal_eval():
    fl = FLConfig(n_clients=4, tau=2, rounds=3, seed=0)
    net = WirelessNetwork(4, (5.0, 10.0), 2.0, 0.0, (30, 60), seed=0)
    r = AsyncRunner(FakeAsyncTrainer(), net, fl, eval_every=4)
    hist = r.run()
    assert sum(r.cohort_sizes) == fl.rounds * fl.tau
    assert all(s == 1 for s in r.cohort_sizes)
    # eval cadence 4 with budget 6 -> records at 4 and a terminal at 6
    assert hist.rounds == [4, 6]
    assert hist.times == sorted(hist.times)


def test_async_runner_windowed_drains_multi_client_cohorts():
    fl = FLConfig(n_clients=6, tau=3, rounds=4, seed=1)
    net = WirelessNetwork(6, (5.0, 10.0), 2.0, 0.0, (30, 60), seed=1)
    r = AsyncRunner(FakeAsyncTrainer(), net, fl, window_secs=30.0,
                    eval_every=5)
    hist = r.run()
    assert sum(r.cohort_sizes) == fl.rounds * fl.tau
    assert hist.meta["mean_cohort"] > 1.0
    assert max(r.cohort_sizes) > 1
    assert hist.rounds[-1] == fl.rounds * fl.tau     # terminal eval
    assert hist.times == sorted(hist.times)


def test_async_runner_count_window_matches_fedbuff_goal():
    fl = FLConfig(n_clients=6, tau=2, rounds=4, seed=2)
    net = WirelessNetwork(6, (5.0,), 2.0, 0.0, (30, 60), seed=2)
    hist = run_fedbuff(FakeAsyncTrainer(), net, fl, window=2, eval_every=8)
    assert hist.meta["window"] == 2
    assert hist.meta["mean_cohort"] == 2.0


def test_feddct_async_carries_stragglers_instead_of_dropping():
    fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=6, mu=0.3,
                  seed=3, beta=1.1)
    net = WirelessNetwork(8, fl.tier_delay_means, fl.delay_std, fl.mu,
                          fl.failure_delay, fl.seed)
    hist = run_feddct_async(FakeAsyncTrainer(), net, fl)
    assert hist.rounds == list(range(1, 7))
    assert hist.times == sorted(hist.times)
    # windows merged something over the run, and at least one round had
    # in-flight stragglers carried over rather than dropped
    assert hist.meta["n_drains"] >= 1
    assert sum(hist.n_stragglers) >= 1


# ---------------------------------------------------------------------------
# history equivalence: runtime window=0 == legacy sequential fedasync
# ---------------------------------------------------------------------------

def _hist_equal(ha, hb):
    assert ha.rounds == hb.rounds
    assert ha.times == hb.times
    assert ha.accuracy == hb.accuracy
    assert ha.n_selected == hb.n_selected


def test_fedasync_window0_history_identical_to_sequential():
    tr, net, fl = _setup()
    hs = run_fedasync_sequential(tr, net, fl, eval_every=3)
    tr2, net2, fl2 = _setup()
    hr = run_fedasync(tr2, net2, fl2, window=0, eval_every=3)
    _hist_equal(hs, hr)
    # budget 4 with cadence 3: both end on a terminal eval at update 4
    assert hr.rounds[-1] == fl.rounds * fl.tau


def test_engine_train_cohort_matches_per_client_snapshots():
    """Cohort rows must equal training each client separately from its
    own start params with its own seed (the async-window contract)."""
    tr, _, fl = _setup()
    eng = make_engine(tr)
    p0 = tr.init_params(0)
    p1 = tr.init_params(1)
    stacked, sizes = eng.train_cohort([p0, p1], [0, 3], [11, 22])
    for i, (start, c, s) in enumerate([(p0, 0, 11), (p1, 3, 22)]):
        solo, solo_sizes = eng.train_clients(start, [c], s)
        for a, b in zip(jax.tree_util.tree_leaves(_row(stacked, i)),
                        jax.tree_util.tree_leaves(_row(solo, 0))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5)
        assert sizes[i] == solo_sizes[0]


@pytest.mark.slow
def test_fedasync_windowed_cnn_integration():
    tr, net, fl = _setup(rounds=3)
    hist = run_fedasync(tr, net, fl, window_secs=15.0, eval_every=4)
    assert hist.meta["mean_cohort"] > 1.0
    assert hist.rounds[-1] == fl.rounds * fl.tau
    assert all(0.0 <= a <= 1.0 for a in hist.accuracy)
