"""Client-sharded distributed execution subsystem.

Device-count-agnostic: the array-level plan/aggregation/shard_map tests
run on whatever devices exist (a 1-device mesh included).  The
trainer-level shard_map tests and the end-to-end history gates need a
multi-device mesh and skip on a single device — run the full suite
with:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m pytest -q tests/test_distributed.py

(conftest skips every other module under a forced device count; the CI
``distributed-8dev`` job runs exactly this invocation.)
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.config.base import FLConfig
from repro.core.aggregation import (staleness_weighted_merge,
                                    weighted_average_stacked)
from repro.core.baselines import (run_fedasync, run_fedasync_sequential,
                                  run_fedavg)
from repro.core.engine import BatchedClientEngine, make_engine
from repro.distributed import (ClientShardingPlan, ensure_host_device_count,
                               forced_host_device_count, make_client_mesh,
                               shard_cohort_train, sharded_aggregate,
                               sharded_staleness_merge)
from repro.distributed.engine import ShardedClientEngine
from repro.fl.client import CNNTrainer
from repro.fl.network import WirelessNetwork
from repro.kernels import fedagg_pytree

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count>=2")

_TRAINER_CACHE = {}


def _setup(rounds=2, n_clients=8, seed=0, lr=0.003, tau=2):
    fl = FLConfig(n_clients=n_clients, n_tiers=4, tau=tau, rounds=rounds,
                  mu=0.0, primary_frac=0.7, seed=seed, lr=lr)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    key = (n_clients, seed, lr)
    if key not in _TRAINER_CACHE:
        _TRAINER_CACHE[key] = CNNTrainer(get_arch("cnn-mnist").reduced(),
                                         fl, "mnist", scale=0.01)
    return _TRAINER_CACHE[key], net, fl


def _stacked_tree(n, seed=0):
    """Mixed-dtype stacked update pytree: 3-d f32, bf16 matrix, scalar."""
    rng = np.random.default_rng(seed)
    return {
        "f32": jnp.asarray(rng.normal(size=(n, 5, 3)).astype(np.float32)),
        "bf16": jnp.asarray(rng.normal(size=(n, 7)).astype(np.float32)
                            ).astype(jnp.bfloat16),
        "scalar": jnp.asarray(rng.normal(size=(n,)).astype(np.float32)),
    }


def _assert_tree_close(a, b, rtol=1e-5, atol=1e-5, bf16_tol=2e-2):
    for k in b:
        tol = dict(rtol=bf16_tol, atol=bf16_tol) if "bf16" in k \
            else dict(rtol=rtol, atol=atol)
        np.testing.assert_allclose(np.asarray(a[k], np.float32),
                                   np.asarray(b[k], np.float32), **tol)


# ---------------------------------------------------------------------------
# XLA_FLAGS plumbing (hostdevices)
# ---------------------------------------------------------------------------

def test_ensure_host_device_count_appends_not_clobbers():
    env = {"XLA_FLAGS": "--xla_cpu_enable_fast_math=false"}
    assert ensure_host_device_count(8, env) == 8
    assert env["XLA_FLAGS"] == ("--xla_cpu_enable_fast_math=false "
                                "--xla_force_host_platform_device_count=8")


def test_ensure_host_device_count_existing_flag_wins():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    assert ensure_host_device_count(16, env) == 4
    assert env["XLA_FLAGS"] == "--xla_force_host_platform_device_count=4"
    assert forced_host_device_count(env) == 4


def test_ensure_host_device_count_empty_env():
    env = {}
    assert ensure_host_device_count(2, env) == 2
    assert env["XLA_FLAGS"] == "--xla_force_host_platform_device_count=2"
    with pytest.raises(ValueError):
        ensure_host_device_count(0, {})


def test_forced_host_device_count_absent():
    assert forced_host_device_count({"XLA_FLAGS": "--foo=1"}) is None
    assert forced_host_device_count({}) is None


# ---------------------------------------------------------------------------
# mesh factory
# ---------------------------------------------------------------------------

def test_make_client_mesh_spans_all_devices():
    mesh = make_client_mesh()
    assert mesh.axis_names == ("clients",)
    assert int(mesh.size) == N_DEV


def test_make_client_mesh_subset_and_clamp():
    assert int(make_client_mesh(1).size) == 1
    assert int(make_client_mesh(10 ** 6).size) == N_DEV   # clamped
    with pytest.raises(ValueError):
        make_client_mesh(0)


def test_make_client_mesh_composes_with_launch_factory():
    from repro.launch.mesh import make_client_mesh as launch_make
    mesh = launch_make(devices=make_client_mesh().devices.flatten())
    assert mesh.axis_names == ("clients",)
    assert int(mesh.size) == N_DEV


# ---------------------------------------------------------------------------
# sharding plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,pow2,expect", [
    (3, 8, False, 8),       # N smaller than the mesh
    (12, 8, False, 16),     # N not divisible by the mesh
    (16, 8, False, 16),     # exact multiple: no padding
    (3, 8, True, 8),        # pow2 then mesh multiple
    (5, 4, True, 8),
    (6, 1, True, 8),        # 1-device mesh: pure pow2 convention
    (7, 3, False, 9),       # non-pow2 mesh still lands on a multiple
])
def test_plan_padding_math(n, d, pow2, expect):
    plan = ClientShardingPlan.for_cohort(n, d, pow2=pow2)
    assert plan.padded_n == expect
    assert plan.padded_n % d == 0
    assert plan.pad_rows == expect - n


def test_plan_rejects_empty_cohort():
    with pytest.raises(ValueError):
        ClientShardingPlan.for_cohort(0, 4)


def test_plan_pad_unpad_roundtrip_edge_and_zero():
    tree = _stacked_tree(5)
    plan = ClientShardingPlan.for_cohort(5, 4)
    for mode in ("edge", "zero"):
        padded = plan.pad_stacked(tree, mode=mode)
        assert {l.shape[0] for l in jax.tree_util.tree_leaves(padded)} == {8}
        back = plan.unpad(padded)
        for k in tree:
            assert back[k].dtype == tree[k].dtype
            np.testing.assert_array_equal(
                np.asarray(back[k], np.float32),
                np.asarray(tree[k], np.float32))
    edge = plan.pad_stacked(tree, mode="edge")
    np.testing.assert_array_equal(np.asarray(edge["f32"][-1]),
                                  np.asarray(tree["f32"][-1]))
    zero = plan.pad_stacked(tree, mode="zero")
    assert float(jnp.abs(zero["f32"][5:]).sum()) == 0.0
    w = plan.pad_weights(np.ones(5, np.float32))
    assert w.shape == (8,)
    assert float(w[5:].sum()) == 0.0
    with pytest.raises(ValueError):
        plan.pad_stacked(tree, mode="wat")


# ---------------------------------------------------------------------------
# sharded aggregation parity (uneven cohorts, mixed dtypes, stragglers)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [3, 5, 12, 16])
def test_sharded_aggregate_matches_reference(n):
    """N < mesh, N not divisible by mesh, N a multiple — all must match
    the single-device reduction within dtype tolerance."""
    mesh = make_client_mesh()
    tree = _stacked_tree(n, seed=n)
    rng = np.random.default_rng(n + 1)
    w = rng.uniform(0.5, 2.0, n).astype(np.float32)
    w[0] = 0.0                                 # masked straggler row
    out = sharded_aggregate(mesh, tree, w)
    ref = weighted_average_stacked(tree, w)
    _assert_tree_close(out, ref)


def test_sharded_aggregate_nonuniform_alphas():
    mesh = make_client_mesh()
    n = 11
    tree = _stacked_tree(n, seed=2)
    rng = np.random.default_rng(3)
    w = rng.uniform(0.5, 2.0, n).astype(np.float32)
    alphas = (0.6 * (np.arange(n) + 1.0) ** -0.5).astype(np.float32)
    alphas[4] = 0.0                            # zero-alpha straggler
    out = sharded_aggregate(mesh, tree, w, alphas=alphas)
    ref = weighted_average_stacked(tree, w, alphas=alphas)
    _assert_tree_close(out, ref)


def test_sharded_aggregate_zero_rows_masked_even_nonfinite():
    mesh = make_client_mesh()
    tree = {"w": jnp.asarray([[1.0, 2.0], [np.nan, np.inf], [3.0, 4.0]],
                             jnp.float32)}
    out = sharded_aggregate(mesh, tree, [1.0, 0.0, 1.0])
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 3.0], rtol=1e-6)


def test_sharded_aggregate_all_masked_is_zeros():
    mesh = make_client_mesh()
    out = sharded_aggregate(mesh, {"w": jnp.ones((4, 9))}, np.zeros(4))
    np.testing.assert_allclose(np.asarray(out["w"]), 0.0, atol=1e-7)


def test_sharded_aggregate_matches_pallas_fedagg():
    mesh = make_client_mesh()
    n = 6
    tree = _stacked_tree(n, seed=5)
    w = np.asarray([1.0, 2.0, 0.0, 3.0, 0.5, 1.5], np.float32)
    out = sharded_aggregate(mesh, tree, w)
    ref = fedagg_pytree(tree, jnp.asarray(w), interpret=True)
    _assert_tree_close(out, ref)


def test_sharded_aggregate_rejects_length_mismatch():
    mesh = make_client_mesh()
    with pytest.raises(ValueError):
        sharded_aggregate(mesh, {"w": jnp.ones((4, 2))}, np.ones(3))


def test_sharded_staleness_merge_matches_reference():
    mesh = make_client_mesh()
    n = 7
    stacked = _stacked_tree(n, seed=8)
    g = jax.tree_util.tree_map(lambda l: l[0] * 0.5, stacked)
    alphas = (0.6 * (np.arange(n, dtype=np.float64) + 1.0) ** -0.5)
    alphas[2] = 0.0                            # carried straggler: no-op row
    out = sharded_staleness_merge(mesh, g, stacked, alphas)
    ref = staleness_weighted_merge(g, stacked, alphas)
    _assert_tree_close(out, ref)


# ---------------------------------------------------------------------------
# per-shard Pallas fedagg dispatch (interpret mode inside shard_map)
# ---------------------------------------------------------------------------

def test_sharded_aggregate_kernel_dispatch_matches_jnp():
    """use_kernel=True reduces each shard's rows through the
    fedagg_partial Pallas kernel (interpret on CPU); the psum combine
    and masking semantics are unchanged."""
    mesh = make_client_mesh()
    n = 9
    tree = _stacked_tree(n, seed=11)
    rng = np.random.default_rng(12)
    w = rng.uniform(0.5, 2.0, n).astype(np.float32)
    w[3] = 0.0                                 # masked straggler row
    out_k = sharded_aggregate(mesh, tree, w, use_kernel=True)
    out_j = sharded_aggregate(mesh, tree, w)
    _assert_tree_close(out_k, out_j)
    ref = weighted_average_stacked(tree, w)
    _assert_tree_close(out_k, ref)


def test_sharded_aggregate_kernel_all_masked_fallback():
    mesh = make_client_mesh()
    fallback = {"w": jnp.asarray([5.0, 6.0], jnp.float32)}
    out = sharded_aggregate(mesh, {"w": jnp.full((4, 2), np.nan)},
                            np.zeros(4), fallback=fallback,
                            use_kernel=True)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(fallback["w"]))


def test_sharded_staleness_merge_kernel_dispatch_matches_reference():
    """The sharded kernel-merge parity case: per-shard fedagg_partial
    partial sums + one psum must match the single-device folded merge
    within float tolerance (runs on whatever mesh exists — the
    forced-8-host-device CI job included)."""
    mesh = make_client_mesh()
    n = 10
    stacked = _stacked_tree(n, seed=13)
    g = jax.tree_util.tree_map(lambda l: l[0] * 0.5, stacked)
    alphas = (0.6 * (np.arange(n, dtype=np.float64) + 1.0) ** -0.5)
    alphas[4] = 0.0                            # carried straggler: no-op row
    out_k = sharded_staleness_merge(mesh, g, stacked, alphas,
                                    use_kernel=True)
    ref = staleness_weighted_merge(g, stacked, alphas)
    _assert_tree_close(out_k, ref)
    out_j = sharded_staleness_merge(mesh, g, stacked, alphas)
    _assert_tree_close(out_k, out_j)


# ---------------------------------------------------------------------------
# shard_cohort_train mechanics (pure functions, no trainer)
# ---------------------------------------------------------------------------

def test_shard_cohort_train_elementwise_parity_uneven():
    mesh = make_client_mesh()

    def train(starts, x):
        return jax.tree_util.tree_map(
            lambda l: l + x[:, :1] ** 2, starts)

    run = shard_cohort_train(mesh, train, replicated=0)
    for n in (2, 5, 16):                       # < mesh, uneven, multiple
        starts = {"w": jnp.arange(float(n * 3)).reshape(n, 3)}
        x = jnp.arange(float(n * 4)).reshape(n, 4)
        out = run(starts, x)
        ref = train(starts, x)
        assert out["w"].shape == (n, 3)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(ref["w"]), rtol=1e-6)


def test_shard_cohort_train_replicated_leading_arg():
    mesh = make_client_mesh()

    def train(params, x):
        return {"w": x * params["scale"]}

    run = shard_cohort_train(mesh, train, replicated=1)
    x = jnp.arange(float(N_DEV * 2 + 1)).reshape(-1, 1)   # uneven rows
    out = run({"scale": jnp.asarray(3.0)}, x)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(x) * 3.0)


def test_shard_cohort_train_requires_sharded_arg():
    mesh = make_client_mesh()
    run = shard_cohort_train(mesh, lambda p: p, replicated=1)
    with pytest.raises(ValueError):
        run({"w": jnp.ones(3)})


# ---------------------------------------------------------------------------
# engine selection
# ---------------------------------------------------------------------------

class _FakeLoopTrainer:
    class cfg:
        arch_id = "fake"

    def init_params(self, seed=0):
        return {"w": jnp.zeros(4, jnp.float32)}

    def local_train(self, params, client_id, rnd_seed):
        return {"w": params["w"] + 1.0 + client_id}, 10 + client_id


def test_make_engine_one_device_mesh_is_plain_engine():
    """The documented single-device guarantee: a 1-device mesh selects
    the existing engine, so histories are bit-identical by
    construction."""
    eng = make_engine(_FakeLoopTrainer(), mesh=make_client_mesh(1))
    assert type(eng) is BatchedClientEngine


def test_make_engine_looped_plus_mesh_rejected_or_passthrough():
    if N_DEV > 1:
        with pytest.raises(ValueError):
            make_engine(_FakeLoopTrainer(), engine="looped",
                        mesh=make_client_mesh())
    eng = make_engine(_FakeLoopTrainer(), engine="looped",
                      mesh=make_client_mesh(1))
    assert eng.force_looped


@multi_device
def test_sharded_engine_kernel_agg_dispatches_per_shard():
    """The sharded engine no longer discards use_kernel_agg: merges run
    the per-shard fedagg_partial dispatch inside the psum reduction and
    match the plain kernel engine."""
    eng = make_engine(_FakeLoopTrainer(), mesh=make_client_mesh(),
                      use_kernel_agg=True)
    assert isinstance(eng, ShardedClientEngine)
    assert eng.use_kernel_agg
    p = {"w": jnp.zeros(4, jnp.float32)}
    out = eng.train_round(p, [1, 3], rnd_seed=0)
    plain = make_engine(_FakeLoopTrainer(), use_kernel_agg=True)
    ref = plain.train_round(p, [1, 3], rnd_seed=0)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(ref["w"]), rtol=1e-5)


@multi_device
def test_make_engine_multi_device_mesh_is_sharded():
    mesh = make_client_mesh()
    eng = make_engine(_FakeLoopTrainer(), mesh=mesh)
    assert isinstance(eng, ShardedClientEngine)
    assert eng.mesh is mesh
    # pad target composes pow2 with the mesh multiple
    assert eng._pad_target(3) % int(mesh.size) == 0


@multi_device
def test_sharded_engine_loop_only_trainer_falls_back():
    """A trainer without the batched paths (or the wrap hook) keeps the
    looped fallback semantics under a multi-device mesh."""
    eng = make_engine(_FakeLoopTrainer(), mesh=make_client_mesh())
    p = {"w": jnp.zeros(4)}
    out = eng.train_round(p, [1, 3], rnd_seed=0)
    expect = (2.0 * 11 + 4.0 * 13) / 24
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full(4, expect, np.float32), rtol=1e-5)


# ---------------------------------------------------------------------------
# trainer-level shard_map parity (forced multi-device CI job)
# ---------------------------------------------------------------------------

@multi_device
def test_cohort16_trains_sharded_and_matches_single_device():
    """The acceptance gate: a 16-client cohort trains under shard_map
    across the client mesh and matches the single-device engine row for
    row; the sharded merge with nonuniform staleness alphas and a
    zero-weight straggler row matches the reference merge."""
    tr, _, fl = _setup(n_clients=16)
    mesh = make_client_mesh()
    sharded = make_engine(tr, mesh=mesh)
    plain = make_engine(tr)
    assert isinstance(sharded, ShardedClientEngine)

    ids = list(range(16))
    seeds = [7 * c + 1 for c in ids]
    starts = [tr.init_params(c % 3) for c in ids]
    s_stacked, s_sizes = sharded.train_cohort(starts, ids, seeds)
    p_stacked, p_sizes = plain.train_cohort(starts, ids, seeds)
    np.testing.assert_array_equal(s_sizes, p_sizes)
    for a, b in zip(jax.tree_util.tree_leaves(s_stacked),
                    jax.tree_util.tree_leaves(p_stacked)):
        assert a.shape[0] == 16
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-5)

    alphas = 0.6 * (np.arange(16, dtype=np.float64) + 1.0) ** -0.5
    alphas[3] = 0.0                            # zero-weight straggler row
    g = tr.init_params(0)
    merged = sharded.merge_staleness(g, s_stacked, alphas)
    ref = plain.merge_staleness(g, p_stacked, alphas)
    for a, b in zip(jax.tree_util.tree_leaves(merged),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


@multi_device
def test_train_clients_sharded_uneven_cohort_matches():
    """Sync path (shared global params, replicated arg) with a cohort
    smaller than the mesh."""
    tr, _, fl = _setup()
    mesh = make_client_mesh()
    sharded = make_engine(tr, mesh=mesh)
    plain = make_engine(tr)
    params = tr.init_params(0)
    s_stacked, s_sizes = sharded.train_clients(params, [0, 1, 2], 1)
    p_stacked, p_sizes = plain.train_clients(params, [0, 1, 2], 1)
    np.testing.assert_array_equal(s_sizes, p_sizes)
    for a, b in zip(jax.tree_util.tree_leaves(s_stacked),
                    jax.tree_util.tree_leaves(p_stacked)):
        assert a.shape[0] == 3
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-5)


@multi_device
def test_fedavg_sharded_history_matches_single_device():
    tr, net, fl = _setup()
    hs = run_fedavg(tr, net, fl, mesh=make_client_mesh())
    tr2, net2, fl2 = _setup()
    hp = run_fedavg(tr2, net2, fl2)
    assert hs.rounds == hp.rounds
    np.testing.assert_allclose(hs.times, hp.times, rtol=1e-9)
    np.testing.assert_allclose(hs.accuracy, hp.accuracy, atol=5e-3)


@multi_device
def test_fedasync_window0_gate_holds_with_one_device_mesh():
    """PR 2 regression gate with the distributed path enabled: a
    1-device client mesh must leave run_fedasync(window=0)
    history-identical to the legacy sequential loop."""
    tr, net, fl = _setup()
    hs = run_fedasync_sequential(tr, net, fl, eval_every=3)
    tr2, net2, fl2 = _setup()
    hr = run_fedasync(tr2, net2, fl2, window=0, eval_every=3,
                      mesh=make_client_mesh(1))
    assert hs.rounds == hr.rounds
    assert hs.times == hr.times
    assert hs.accuracy == hr.accuracy
    assert hs.n_selected == hr.n_selected


@multi_device
def test_client_state_store_sharded_matches_plain():
    """The store's row axis shards over the client mesh (rows padded to
    a mesh multiple via ClientShardingPlan); gathers/scatters and the
    fused merge+scatter must match the single-device store within
    float tolerance."""
    from repro.core.aggregation import staleness_merge_coefficients
    from repro.core.state import ClientStateStore
    mesh = make_client_mesh()
    template = {"f32": jnp.asarray(np.arange(15.0, dtype=np.float32)
                                   .reshape(5, 3)),
                "bf16": jnp.asarray(np.arange(7.0, dtype=np.float32)
                                    ).astype(jnp.bfloat16),
                "scalar": jnp.float32(0.5)}
    other = jax.tree_util.tree_map(lambda l: l * 2.0 + 1.0, template)
    plain = ClientStateStore(template, 12)
    shard = ClientStateStore(template, 12, mesh=mesh)
    assert shard.rows % int(mesh.size) == 0 and shard.rows >= 12

    for s in (plain, shard):
        s.scatter_params([3, 5], other)
    for c in (0, 3, 5, 11):
        _assert_tree_close(shard.gather_one(c), plain.gather_one(c),
                           rtol=0, atol=0, bf16_tol=0)

    # stacked updates share the template's structure / per-row shapes
    stacked = {"f32": jnp.broadcast_to(template["f32"], (8, 5, 3)) * 1.1,
               "bf16": (jnp.ones((8, 7), jnp.float32) * 0.3
                        ).astype(jnp.bfloat16),
               "scalar": jnp.arange(8.0, dtype=jnp.float32)}
    alphas = 0.6 * (np.arange(8, dtype=np.float64) + 1.0) ** -0.5
    alphas[2] = 0.0
    coef = staleness_merge_coefficients(alphas)
    ids = list(range(8))
    pp, _ = plain.merge_scatter(ids, stacked, coef, template)
    ps, _ = shard.merge_scatter(ids, stacked, coef, template)
    _assert_tree_close(ps, pp)
    _assert_tree_close(shard.gather_one(4), plain.gather_one(4))


@multi_device
def test_fedasync_windowed_sharded_matches_single_device():
    """Windowed async cohorts train sharded and merge within tolerance
    of the single-device runtime."""
    tr, net, fl = _setup(seed=1)
    hs = run_fedasync(tr, net, fl, window_secs=20.0, eval_every=4,
                      mesh=make_client_mesh())
    tr2, net2, fl2 = _setup(seed=1)
    hp = run_fedasync(tr2, net2, fl2, window_secs=20.0, eval_every=4)
    assert hs.rounds == hp.rounds
    assert hs.times == hp.times
    assert hs.meta["mean_cohort"] == hp.meta["mean_cohort"]
    np.testing.assert_allclose(hs.accuracy, hp.accuracy, atol=5e-3)


@multi_device
def test_fedasync_windowed_sharded_kernel_store_matches_single_device():
    """Everything at once: client-mesh sharded training, the
    row-sharded store, and the Pallas kernel merge dispatch — within
    tolerance of the plain single-device kernel runtime."""
    tr, net, fl = _setup(seed=1)
    hs = run_fedasync(tr, net, fl, window_secs=20.0, eval_every=4,
                      mesh=make_client_mesh(), use_kernel_agg=True)
    assert hs.meta["store_path"] == "store"
    tr2, net2, fl2 = _setup(seed=1)
    hp = run_fedasync(tr2, net2, fl2, window_secs=20.0, eval_every=4,
                      use_kernel_agg=True)
    assert hs.rounds == hp.rounds
    assert hs.times == hp.times
    assert hs.meta["mean_cohort"] == hp.meta["mean_cohort"]
    np.testing.assert_allclose(hs.accuracy, hp.accuracy, atol=5e-3)
