"""Data pipeline: synthetic sets, partitioners (seeded sweeps), batching."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import (client_batches, dirichlet_partition,
                        make_image_dataset, make_token_dataset,
                        primary_class_partition)
from repro.data.pipeline import ClientDataset


def test_image_dataset_shapes_and_determinism():
    d1 = make_image_dataset("mnist", seed=0, scale=0.01)
    d2 = make_image_dataset("mnist", seed=0, scale=0.01)
    assert d1["x_train"].shape == (600, 28, 28, 1)
    assert d1["x_test"].shape == (100, 28, 28, 1)
    np.testing.assert_array_equal(d1["x_train"], d2["x_train"])
    d3 = make_image_dataset("cifar10", seed=0, scale=0.01)
    assert d3["x_train"].shape == (500, 32, 32, 3)


def _run_digest_subprocess(hashseed: str) -> str:
    """Hash the synthetic dataset + partition in a FRESH interpreter
    with an explicit PYTHONHASHSEED — the cross-process reproducibility
    the in-process determinism test above cannot see."""
    code = (
        "import hashlib, numpy as np\n"
        "from repro.data import make_image_dataset, "
        "primary_class_partition\n"
        "d = make_image_dataset('mnist', seed=0, scale=0.002)\n"
        "parts = primary_class_partition(d['y_train'], 4, 0.7, seed=0)\n"
        "h = hashlib.sha256()\n"
        "for k in ('x_train', 'y_train', 'x_test', 'y_test'):\n"
        "    h.update(np.ascontiguousarray(d[k]).tobytes())\n"
        "for p in parts:\n"
        "    h.update(np.ascontiguousarray(np.asarray(p)).tobytes())\n"
        "print(h.hexdigest())\n")
    env = dict(os.environ, PYTHONHASHSEED=hashseed,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    return out.stdout.strip()


def test_image_dataset_identical_across_processes():
    """Regression: the dataset seed salt used builtin ``hash(name)``,
    which PYTHONHASHSEED randomizes per process — same flags produced
    different pixels (and different final accuracy) in every new
    ``fl_train.py`` process.  Two interpreters with different hash
    seeds must now agree byte for byte."""
    d1 = _run_digest_subprocess("1")
    d2 = _run_digest_subprocess("2")
    assert d1 == d2


def test_classes_are_separable_by_prototype_distance():
    d = make_image_dataset("mnist", seed=0, scale=0.02)
    x, y = d["x_train"], d["y_train"]
    # class-conditional means differ far more than within-class noise
    mus = np.stack([x[y == c].mean(0) for c in range(10)])
    diff = mus[:, None] - mus[None]
    between = np.sqrt((diff ** 2).sum(axis=(2, 3, 4)))
    assert np.median(between[np.triu_indices(10, 1)]) > 1.0


@pytest.mark.parametrize("n_clients,frac", [
    (2, 0.15), (2, 0.95), (3, 0.5), (5, 0.7), (8, 0.33), (10, 0.9),
    (13, 0.15), (17, 0.62), (24, 0.8), (30, 0.95), (30, 0.15), (7, 0.45),
])
def test_primary_partition_properties(n_clients, frac):
    labels = np.random.default_rng(0).integers(0, 10, 3000).astype(np.int64)
    parts = primary_class_partition(labels, n_clients, frac, seed=1)
    allidx = np.concatenate(parts)
    # disjoint
    assert len(np.unique(allidx)) == len(allidx)
    # primary class holds ~frac of each client's samples, BOUNDED BY the
    # class pool: with n_clients small, per_client can exceed the ~300
    # samples a class has, and clients sharing a primary deplete it —
    # both are inherent to the paper's random assignment.
    per_client = 3000 // n_clients
    achievable = min(frac, (3000 / 10) / per_client)
    fracs = []
    for p in parts:
        if len(p) < 20:
            continue
        counts = np.bincount(labels[p], minlength=10)
        fracs.append(counts.max() / len(p))
    if fracs:
        assert max(fracs) >= achievable - 0.15


def test_primary_partition_iid_when_frac_low():
    labels = np.random.default_rng(0).integers(0, 10, 1000)
    parts = primary_class_partition(labels, 10, 0.05, seed=0)
    assert sum(len(p) for p in parts) == 1000


def test_dirichlet_partition_covers_everything():
    labels = np.random.default_rng(0).integers(0, 10, 2000)
    parts = dirichlet_partition(labels, 8, alpha=0.5, seed=0)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx) == 2000


def test_client_batches_epoch():
    ds = ClientDataset(np.arange(37)[:, None].astype(np.float32),
                       np.arange(37) % 3)
    batches = list(client_batches(ds, 10, epoch_seed=0))
    assert len(batches) == 3
    assert all(len(b[1]) == 10 for b in batches)


def test_token_dataset_has_structure():
    toks = make_token_dataset(256, 20_000, seed=0)
    assert toks.min() >= 0 and toks.max() < 256
    # Markov structure: repeated-context bigram entropy < unigram entropy
    uni = np.bincount(toks, minlength=256) / len(toks)
    h_uni = -(uni[uni > 0] * np.log(uni[uni > 0])).sum()
    pair_counts = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pair_counts.setdefault(int(a), []).append(int(b))
    h_cond = []
    for a, bs in pair_counts.items():
        if len(bs) < 20:
            continue
        p = np.bincount(bs, minlength=256) / len(bs)
        h_cond.append(-(p[p > 0] * np.log(p[p > 0])).sum())
    assert np.mean(h_cond) < h_uni - 0.5
