
# A forced host device count (the distributed suite's
# XLA_FLAGS=--xla_force_host_platform_device_count=8 run) is only
# meaningful for tests/test_distributed.py — every other module assumes
# the real (single) CPU device.  Instead of refusing outright, skip the
# rest of the suite so the documented multi-device invocation works.
# (hostdevices is jax-free, so this import cannot init the backend.)
from repro.distributed.hostdevices import forced_host_device_count

_FORCED_DEVICES = forced_host_device_count() is not None

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_collection_modifyitems(config, items):
    if not _FORCED_DEVICES:
        return
    skip = pytest.mark.skip(
        reason="forced host device count: only tests/test_distributed.py "
               "is device-count-agnostic")
    for item in items:
        if "test_distributed" not in str(item.fspath):
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
