import os

# Tests must see the real (single) CPU device — the 512-device override
# belongs to launch/dryrun.py ONLY.
assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", "")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
