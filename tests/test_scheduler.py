"""FedDCT scheduler mechanics with a fake (instant) trainer."""

import numpy as np

from repro.config.base import FLConfig
from repro.core.baselines import run_fedasync, run_fedavg, run_tifl
from repro.core.scheduler import run_feddct
from repro.fl.network import WirelessNetwork


class FakeTrainer:
    """No real learning: params is a counter; accuracy rises with rounds."""

    class cfg:
        arch_id = "fake"

    def __init__(self):
        self.n_evals = 0
        self.trained = []

    def init_params(self, seed=0):
        return {"w": np.zeros(4, np.float32)}

    def local_train(self, params, client_id, rnd_seed):
        self.trained.append(client_id)
        return {"w": params["w"] + 1.0}, 10

    def evaluate(self, params, **kw):
        self.n_evals += 1
        return min(0.01 * self.n_evals, 0.99)


def _fl(**kw):
    base = dict(n_clients=20, n_tiers=4, tau=2, rounds=10, kappa=1,
                omega=30.0, beta=1.2, seed=3)
    base.update(kw)
    return FLConfig(**base)


def _net(fl, mu=0.0):
    return WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                           mu, fl.failure_delay, fl.seed)


def test_feddct_runs_and_clock_monotone():
    fl = _fl()
    hist = run_feddct(FakeTrainer(), _net(fl), fl)
    assert len(hist.accuracy) == fl.rounds
    assert all(b >= a for a, b in zip(hist.times, hist.times[1:]))
    assert all(1 <= t <= fl.n_tiers for t in hist.tier)


def test_feddct_round_time_capped_by_omega():
    fl = _fl(rounds=6)
    hist = run_feddct(FakeTrainer(), _net(fl, mu=0.9), fl)
    # per round the clock can advance at most omega (Eq. 5/6 cap)
    deltas = np.diff([0] + hist.times)
    # first delta includes the parallel profiling setup
    assert all(d <= fl.omega + 1e-6 for d in deltas[1:])


def test_feddct_stragglers_do_not_contribute():
    fl = _fl(rounds=8)
    tr = FakeTrainer()
    hist = run_feddct(tr, _net(fl, mu=0.8), fl)
    assert sum(hist.n_stragglers) > 0         # failures actually happened


def test_feddct_faster_than_fedavg_with_stragglers():
    """The paper's core claim, in miniature: same rounds, same network,
    FedDCT's virtual clock ends earlier than FedAvg's."""
    fl = _fl(rounds=10)
    t_dct = run_feddct(FakeTrainer(), _net(fl, mu=0.4), fl).times[-1]
    t_avg = run_fedavg(FakeTrainer(), _net(fl, mu=0.4), fl).times[-1]
    assert t_dct < t_avg


def test_tier_pointer_moves_up_when_accuracy_stalls():
    class Stall(FakeTrainer):
        def evaluate(self, params, **kw):
            self.n_evals += 1
            return 0.5 if self.n_evals % 2 else 0.1  # oscillates down

    fl = _fl(rounds=12)
    hist = run_feddct(Stall(), _net(fl), fl)
    assert max(hist.tier) > 1                # regression pushed tier up


def test_baselines_run():
    fl = _fl(rounds=4)
    for fn in (run_fedavg, run_tifl):
        h = fn(FakeTrainer(), _net(fl, mu=0.2), fl)
        assert len(h.accuracy) == fl.rounds
    h = run_fedasync(FakeTrainer(), _net(fl, mu=0.2), fl, eval_every=2)
    assert len(h.accuracy) >= 1


def test_tifl_drops_permanent_stragglers():
    fl = _fl(rounds=4)
    # group means put last group far beyond omega
    net = WirelessNetwork(fl.n_clients, (1.0, 2.0, 3.0, 100.0),
                          0.1, 0.0, (30, 60), fl.seed)
    tr = FakeTrainer()
    run_tifl(tr, net, fl)
    dropped = set(range(15, 20))             # the 100s group
    assert not (set(tr.trained) & dropped)


def test_fedasync_clock_is_event_driven():
    fl = _fl(rounds=3)
    h = run_fedasync(FakeTrainer(), _net(fl), fl, eval_every=1)
    assert all(b >= a for a, b in zip(h.times, h.times[1:]))
