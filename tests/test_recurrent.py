"""mLSTM chunkwise vs sequential oracle; SSM chunked vs ref; sLSTM
stability; decode handoff equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ssm_scan_ref
from repro.models.ssm import init_ssm, ssm_core, ssm_decode_step, ssm_forward
from repro.models.xlstm import init_slstm, mlstm_core, slstm_scan

KEY = jax.random.PRNGKey(0)


def _mlstm_sequential(q, k, v, logi, logf):
    """Direct per-step recurrence oracle (stabilized)."""
    bsz, hh, s, dh = q.shape
    k = k / np.sqrt(dh)
    C = np.zeros((bsz, hh, dh, dh), np.float64)
    n = np.zeros((bsz, hh, dh), np.float64)
    m = np.full((bsz, hh), -1e30, np.float64)
    q, k, v = (np.asarray(x, np.float64) for x in (q, k, v))
    logi, logf = np.asarray(logi, np.float64), np.asarray(logf, np.float64)
    hs = np.zeros_like(q)
    for t in range(s):
        m_new = np.maximum(logf[..., t] + m, logi[..., t])
        f = np.exp(logf[..., t] + m - m_new)
        i = np.exp(logi[..., t] - m_new)
        C = f[..., None, None] * C + i[..., None, None] * (
            k[:, :, t, :, None] * v[:, :, t, None, :])
        n = f[..., None] * n + i[..., None] * k[:, :, t]
        num = np.einsum("bhd,bhde->bhe", q[:, :, t], C)
        den = np.einsum("bhd,bhd->bh", q[:, :, t], n)
        hs[:, :, t] = num / np.maximum(np.abs(den), np.exp(-m_new))[..., None]
        m = m_new
    return hs


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (48, 48), (40, 8)])
def test_mlstm_chunked_matches_sequential(s, chunk):
    b, h, dh = 2, 3, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, h, s, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, dh), jnp.float32)
    logi = jax.random.normal(ks[3], (b, h, s), jnp.float32)
    logf = jax.nn.log_sigmoid(
        jax.random.normal(ks[4], (b, h, s), jnp.float32) + 2.0)
    out, _ = mlstm_core(q, k, v, logi, logf, None, chunk=chunk)
    ref = _mlstm_sequential(q, k, v, logi, logf)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_mlstm_chunk_size_invariance():
    b, h, s, dh = 1, 2, 64, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, h, s, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, dh), jnp.float32)
    logi = jax.random.normal(ks[3], (b, h, s), jnp.float32)
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, h, s)) + 2.0)
    a, car_a = mlstm_core(q, k, v, logi, logf, None, chunk=8)
    bb, car_b = mlstm_core(q, k, v, logi, logf, None, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=2e-4,
                               atol=2e-4)
    for x, y in zip(car_a[:2], car_b[:2]):
        # stabilizer m may differ; compare destabilized states
        pass
    np.testing.assert_allclose(
        np.asarray(car_a[0] * jnp.exp(car_a[2])[..., None, None]),
        np.asarray(car_b[0] * jnp.exp(car_b[2])[..., None, None]),
        rtol=2e-4, atol=2e-4)


def test_slstm_long_sequence_stays_finite():
    d, h = 16, 4
    p = init_slstm(jax.random.PRNGKey(1), d, h)
    x = jax.random.normal(KEY, (2, 512, d), jnp.float32) * 3.0
    out, st = slstm_scan(p, x, h)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(jnp.isfinite(st["c"])))


def test_slstm_state_handoff():
    """scan(x) == scan(x1) then scan(x2, state)."""
    d, h = 8, 2
    p = init_slstm(jax.random.PRNGKey(1), d, h)
    x = jax.random.normal(KEY, (1, 20, d), jnp.float32)
    full, _ = slstm_scan(p, x, h)
    a, st = slstm_scan(p, x[:, :9], h)
    b, _ = slstm_scan(p, x[:, 9:], h, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)


def test_ssm_core_matches_ref():
    b, s, d, n = 2, 64, 16, 4
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, d), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bc = jax.random.normal(ks[2], (b, s, 2 * n), jnp.float32)
    p = {"A_log": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                          )[None].repeat(d, 0)}
    y, _ = ssm_core(p, x, dt, bc, None, n, chunk=16)
    yr = ssm_scan_ref(x, dt, bc[..., :n], bc[..., n:], p["A_log"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)


def test_ssm_forward_decode_handoff():
    """Full-seq forward == prefix forward + per-step decode."""
    d, n = 16, 4
    p = init_ssm(jax.random.PRNGKey(2), d, n)
    x = jax.random.normal(KEY, (1, 12, d), jnp.float32)
    full, _ = ssm_forward(p, x, n_state=n, chunk=4)
    pre, st = ssm_forward(p, x[:, :7], n_state=n, chunk=7)
    outs = [pre]
    for t in range(7, 12):
        y, st = ssm_decode_step(p, x[:, t:t + 1], st, n_state=n)
        outs.append(y)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
