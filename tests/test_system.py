"""System-level behaviour: the paper's qualitative claims, end-to-end,
plus dry-run plumbing on the host mesh."""

import json
import subprocess
import sys

import numpy as np
import pytest

from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.network import WirelessNetwork


def _run(method, mu, rounds=6, seed=0, scale=0.01):
    fl = FLConfig(n_clients=10, n_tiers=5, tau=2, rounds=rounds, mu=mu,
                  primary_frac=0.7, seed=seed, lr=0.003)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    tr = build_fl_clients("cnn-mnist", fl, scale=scale)
    return run_method(method, tr, net, fl)


def test_claim_feddct_round_time_bounded():
    """FedDCT never waits past min(tier timeout, Omega) per round even at
    mu=0.8 (paper Fig. 6 robustness)."""
    h = _run("feddct", mu=0.8)
    deltas = np.diff([0] + h.times)
    assert max(deltas[1:]) <= 30.0 + 1e-6


@pytest.mark.slow
def test_claim_fedavg_suffers_from_stragglers():
    """FedAvg round time grows with mu; FedDCT's barely moves."""
    t_avg_0 = np.mean(np.diff(_run("fedavg", mu=0.0).times))
    t_avg_8 = np.mean(np.diff(_run("fedavg", mu=0.8).times))
    t_dct_0 = np.mean(np.diff(_run("feddct", mu=0.0).times[1:]))
    t_dct_8 = np.mean(np.diff(_run("feddct", mu=0.8).times[1:]))
    assert t_avg_8 > t_avg_0 + 10          # fedavg blows up
    assert t_dct_8 - t_dct_0 < t_avg_8 - t_avg_0   # feddct more robust


@pytest.mark.slow
def test_claim_tier_trace_recorded():
    h = _run("feddct", mu=0.1, rounds=8)
    assert len(h.tier) == 8
    assert all(1 <= t <= 5 for t in h.tier)


@pytest.mark.slow
def test_dryrun_subprocess_single_combo(tmp_path):
    """The real multi-pod dry-run in a subprocess (512 fake devices)."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
           "xlstm-350m", "--shape", "decode_32k", "--mesh", "multi",
           "--out", str(tmp_path)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / "xlstm-350m_decode_32k_multi.json"))
    assert rec["status"] == "ok"
    assert rec["mesh"] == "2x16x16"
    assert rec["roofline"]["bound_s"] > 0
