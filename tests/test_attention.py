"""Attention paths agree: naive == chunked == banded; decode ring cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (banded_attention, chunked_attention,
                                    decode_attention, init_kv_cache,
                                    naive_attention, repeat_kv,
                                    update_kv_cache)

KEY = jax.random.PRNGKey(0)


def _qkv(b, s, h, d, t=None):
    t = t or s
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (b, s, h, d), jnp.float32),
            jax.random.normal(ks[1], (b, t, h, d), jnp.float32),
            jax.random.normal(ks[2], (b, t, h, d), jnp.float32))


@pytest.mark.parametrize("cq,ckv", [
    (64, 64),
    pytest.param(128, 256, marks=pytest.mark.slow),
    pytest.param(256, 128, marks=pytest.mark.slow),
])
def test_chunked_matches_naive_causal(cq, ckv):
    q, k, v = _qkv(2, 512, 4, 32)
    a = chunked_attention(q, k, v, causal=True, chunk_q=cq, chunk_kv=ckv)
    b = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_chunked_matches_naive_bidirectional():
    q, k, v = _qkv(2, 256, 2, 16)
    a = chunked_attention(q, k, v, causal=False, chunk_q=64, chunk_kv=64)
    b = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("window", [64, 200, 384])
def test_banded_matches_naive_window(window):
    q, k, v = _qkv(2, 512, 2, 16)
    a = banded_attention(q, k, v, window=window, chunk_q=128, chunk_kv=128)
    b = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("h,s,d", [
    (2, 128, 16), (4, 256, 16),
    pytest.param(2, 256, 32, marks=pytest.mark.slow),
    pytest.param(4, 128, 32, marks=pytest.mark.slow),
    pytest.param(8, 128, 32, marks=pytest.mark.slow),
    pytest.param(8, 256, 16, marks=pytest.mark.slow),
])
def test_chunked_property(h, s, d):
    q, k, v = _qkv(1, s, h, d)
    a = chunked_attention(q, k, v, causal=True, chunk_q=64, chunk_kv=64)
    b = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5,
                               atol=3e-5)


def test_repeat_kv():
    k = jnp.arange(2 * 4 * 2 * 3, dtype=jnp.float32).reshape(2, 4, 2, 3)
    out = repeat_kv(k, 6)
    assert out.shape == (2, 4, 6, 3)
    np.testing.assert_array_equal(np.asarray(out[:, :, 0]),
                                  np.asarray(out[:, :, 1]))


def test_decode_ring_cache_matches_full_attention():
    """Sequential decode through a ring cache == full causal attention."""
    b, s, hq, hkv, d = 1, 24, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    full = naive_attention(q, repeat_kv(k, hq), repeat_kv(v, hq), causal=True)
    cache = init_kv_cache(b, s, hkv, d, dtype=jnp.float32)
    outs = []
    for t in range(s):
        cache = update_kv_cache(cache, k[:, t:t + 1], v[:, t:t + 1],
                                jnp.asarray(t))
        outs.append(decode_attention(q[:, t:t + 1], cache, jnp.asarray(t)))
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_decode_ring_cache_window_eviction():
    """With window W and cache size W, old entries are overwritten and the
    result equals windowed attention over the full history."""
    b, s, h, d, w = 1, 32, 2, 8, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    full = naive_attention(q, k, v, causal=True, window=w)
    cache = init_kv_cache(b, w, h, d, dtype=jnp.float32)
    outs = []
    for t in range(s):
        cache = update_kv_cache(cache, k[:, t:t + 1], v[:, t:t + 1],
                                jnp.asarray(t))
        outs.append(decode_attention(q[:, t:t + 1], cache, jnp.asarray(t),
                                     window=w))
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_context_parallel_matches_naive():
    """chunked_attention_cp (q-chunk axis shardable) == naive."""
    from repro.models.attention import chunked_attention_cp
    q, k, v = _qkv(2, 512, 6, 16)
    a = chunked_attention_cp(q, k, v, causal=True, chunk_q=128,
                             chunk_kv=128)
    b = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("window", [64, 200])
def test_banded_context_parallel_matches_naive(window):
    from repro.models.attention import banded_attention_cp
    q, k, v = _qkv(2, 512, 5, 16)
    a = banded_attention_cp(q, k, v, window=window, chunk_q=128,
                            chunk_kv=128)
    b = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
