"""fedlint: fixture-driven rule tests, waiver parser, CLI/JSON schema,
and the self-check gate (the shipped tree must lint clean).

Fixture sources are written to tmp files and linted under a chosen
*display* path, because most rules scope by relative path (FED002 only
fires in hot-path modules, FED003 only in kernels/state, ...).  Waiver
comments inside fixtures are built by string concatenation so this
file's own raw lines never match the waiver scanner.
"""

from __future__ import annotations

import json
import textwrap

from repro import obs
from repro.analysis.core import lint_file
from repro.analysis.fedlint import main as fedlint_main
from repro.analysis.rules import RULES
from repro.analysis.waivers import META_RULE, parse_waivers
from repro.obs import catalogue, flstats

# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

ALL_CODES = {r.code for r in RULES}


def waive(codes: str, reason: str = "fixture-approved") -> str:
    # concatenated so this test file's source never contains a literal
    # waiver comment (the scanner reads raw lines, not the AST)
    return "# fed" + "lint: disable=" + codes + " -- " + reason


def lint(tmp_path, src: str, rel: str, select=None):
    p = tmp_path / "fx.py"
    p.write_text(textwrap.dedent(src))
    rules = RULES if select is None else [r for r in RULES
                                          if r.code in select]
    return lint_file(str(p), rel, rules)


def only(findings, code: str):
    return [f for f in findings if f.rule == code]


def unwaived(findings, code: str):
    return [f for f in findings if f.rule == code and not f.waived]


# ---------------------------------------------------------------------------
# waiver parser
# ---------------------------------------------------------------------------

def test_waiver_parse_codes_and_reason():
    ws = parse_waivers(["x = 1  " + waive("FED001,FED002", "two codes")])
    assert list(ws) == [1]
    w = ws[1]
    assert w.codes == ("FED001", "FED002")
    assert w.reason == "two codes"
    assert w.valid and not w.used


def test_waiver_missing_reason_is_invalid():
    ws = parse_waivers(["x = 1  # fed" + "lint: disable=FED001"])
    assert not ws[1].valid
    assert any("reason" in p for p in ws[1].problems)


def test_waiver_malformed_code_is_invalid():
    ws = parse_waivers(["x = 1  " + waive("BOGUS", "oops")])
    assert any("malformed" in p for p in ws[1].problems)


def test_waiver_empty_codes_is_invalid():
    ws = parse_waivers(["x = 1  # fed" + "lint: disable= -- why"])
    assert any("no rule codes" in p for p in ws[1].problems)


def test_unused_waiver_is_meta_finding(tmp_path):
    fs = lint(tmp_path, "x = 1  " + waive("FED006", "nothing here") + "\n",
              "src/repro/core/fx.py")
    assert any("unused waiver" in f.message for f in only(fs, META_RULE))


def test_unused_waiver_silent_when_rule_not_active(tmp_path):
    fs = lint(tmp_path, "x = 1  " + waive("FED006", "nothing here") + "\n",
              "src/repro/core/fx.py", select={"FED007"})
    assert not only(fs, META_RULE)


def test_syntax_error_is_meta_finding(tmp_path):
    fs = lint(tmp_path, "def broken(:\n", "src/repro/core/fx.py")
    assert only(fs, META_RULE)
    assert "syntax error" in fs[0].message


# ---------------------------------------------------------------------------
# FED001 — donation contract
# ---------------------------------------------------------------------------

FED001_POS = """
    def flush(store, ids, rows):
        buf = store.buffer
        store.merge_scatter(ids, rows)
        return buf.sum()
"""


def test_fed001_use_after_scatter(tmp_path):
    fs = lint(tmp_path, FED001_POS, "src/repro/core/fx.py")
    assert len(unwaived(fs, "FED001")) == 1
    assert "donation contract" in fs[0].message


def test_fed001_use_before_scatter_ok(tmp_path):
    src = """
        def flush(store, ids, rows):
            buf = store.buffer
            total = buf.sum()
            store.merge_scatter(ids, rows)
            fresh = store.gather(ids)
            return total + fresh.sum()
    """
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"), "FED001")


def test_fed001_rebind_clears_held_ref(tmp_path):
    src = """
        def flush(store, ids, rows):
            buf = store.buffer
            buf = rows
            store.merge_scatter(ids, rows)
            return buf.sum()
    """
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"), "FED001")


def test_fed001_waived(tmp_path):
    src = FED001_POS.replace("return buf.sum()",
                             "return buf.sum()  "
                             + waive("FED001", "store not donating here"))
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert not unwaived(fs, "FED001")
    assert only(fs, "FED001")[0].waived


# ---------------------------------------------------------------------------
# FED002 — host sync in hot paths
# ---------------------------------------------------------------------------

def test_fed002_item_in_hot_module(tmp_path):
    src = """
        def poll(x):
            return x.item()
    """
    fs = lint(tmp_path, src, "src/repro/core/engine.py")
    assert len(unwaived(fs, "FED002")) == 1
    assert ".item()" in fs[0].message


def test_fed002_not_applied_outside_hot_paths(tmp_path):
    src = """
        def poll(x):
            return x.item()
    """
    assert not only(lint(tmp_path, src, "src/repro/fl/network.py"),
                    "FED002")


def test_fed002_asarray_host_literal_exempt(tmp_path):
    src = """
        import numpy as np

        def pack(xs):
            return np.asarray([x for x in xs])
    """
    assert not only(lint(tmp_path, src, "src/repro/core/engine.py"),
                    "FED002")


def test_fed002_asarray_device_value_flagged(tmp_path):
    src = """
        import numpy as np

        def pull(dev_rows):
            return np.asarray(dev_rows)
    """
    fs = lint(tmp_path, src, "src/repro/core/engine.py")
    assert len(unwaived(fs, "FED002")) == 1


def test_fed002_residency_allowlist(tmp_path):
    src = """
        import numpy as np

        def _ensure_hot(self, rows):
            return np.asarray(rows)
    """
    assert not only(lint(tmp_path, src, "src/repro/core/residency.py"),
                    "FED002")


def test_fed002_float_on_traced_and_block_until_ready(tmp_path):
    src = """
        import jax.numpy as jnp

        def norm(x):
            return float(jnp.sum(x))

        def sync(y):
            y.block_until_ready()
    """
    fs = lint(tmp_path, src, "src/repro/core/state.py")
    msgs = [f.message for f in unwaived(fs, "FED002")]
    assert len(msgs) == 2
    assert any("float()" in m for m in msgs)
    assert any("block_until_ready" in m for m in msgs)


# ---------------------------------------------------------------------------
# FED003 — FMA-contraction hazard
# ---------------------------------------------------------------------------

# the PR 6 fused-merge shape: acc*corr + upd*w drifted 1 ulp between
# the (3,P) and (6,P) compilation units
FED003_FUSED_MERGE = """
    def merge(acc, corr, upd, w):
        return acc * corr + upd * w
"""


def test_fed003_fused_merge_regression(tmp_path):
    fs = lint(tmp_path, FED003_FUSED_MERGE, "src/repro/kernels/fused.py")
    assert len(unwaived(fs, "FED003")) == 1
    assert "FMA" in fs[0].message


def test_fed003_add_feeding_mul_ok(tmp_path):
    src = """
        def dequant(q, snap, scale):
            return (q + snap) * scale
    """
    assert not only(lint(tmp_path, src, "src/repro/kernels/fused.py"),
                    "FED003")


def test_fed003_not_applied_outside_kernels_and_state(tmp_path):
    assert not only(lint(tmp_path, FED003_FUSED_MERGE,
                         "src/repro/fl/network.py"), "FED003")


def test_fed003_state_host_int_arithmetic_exempt(tmp_path):
    src = """
        def nbytes(n, d):
            return n * d + 16
    """
    assert not only(lint(tmp_path, src, "src/repro/core/state.py"),
                    "FED003")


def test_fed003_state_traced_context_flagged(tmp_path):
    src = """
        import jax.numpy as jnp

        def blend(a, b, t):
            y = a * t + b
            return jnp.tanh(y)
    """
    fs = lint(tmp_path, src, "src/repro/core/state.py")
    assert len(unwaived(fs, "FED003")) == 1


def test_fed003_tuple_repetition_exempt(tmp_path):
    src = """
        def shape(n):
            return (1,) * n + (2,)
    """
    assert not only(lint(tmp_path, src, "src/repro/kernels/fx.py"),
                    "FED003")


def test_fed003_waived(tmp_path):
    src = FED003_FUSED_MERGE.replace(
        "return acc * corr + upd * w",
        "return acc * corr + upd * w  "
        + waive("FED003", "tolerance-gated"))
    fs = lint(tmp_path, src, "src/repro/kernels/fused.py")
    assert not unwaived(fs, "FED003")
    assert only(fs, "FED003")[0].reason == "tolerance-gated"


# ---------------------------------------------------------------------------
# FED004 — telemetry overhead + catalogue
# ---------------------------------------------------------------------------

def test_fed004_unguarded_fstring(tmp_path):
    src = '''
        def f(tel, n):
            tel.inc(f"count_{n}", 1)
    '''
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert len(unwaived(fs, "FED004")) == 1
    assert "f-string" in fs[0].message


def test_fed004_enabled_guard_allows_heavy_args(tmp_path):
    src = '''
        def f(tel, n):
            if tel.enabled:
                tel.inc(f"count_{n}", 1)
    '''
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"),
                    "FED004")


def test_fed004_early_return_guard(tmp_path):
    src = '''
        def f(tel, n):
            if not tel.enabled:
                return
            tel.span(f"phase_{n}")
    '''
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"),
                    "FED004")


def test_fed004_call_bearing_argument(tmp_path):
    src = '''
        def f(tel, q):
            tel.gauge("queue.depth", depth_of(q))
    '''
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert len(unwaived(fs, "FED004")) == 1
    assert "call-bearing" in fs[0].message


def test_fed004_cheap_calls_allowed(tmp_path):
    src = '''
        def f(tel, q):
            tel.gauge("queue.depth", len(q))
    '''
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"),
                    "FED004")


def test_fed004_uncatalogued_name(tmp_path):
    src = '''
        def f(tel):
            tel.inc("fl.bogus.counter", 1)
    '''
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert len(unwaived(fs, "FED004")) == 1
    assert "catalogue" in fs[0].message


def test_fed004_counter_prefixes_admitted(tmp_path):
    src = '''
        def f(tel):
            tel.inc("jax.cache.miss", 1)
            tel.inc("telemetry.dropped_spans", 3)
    '''
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"),
                    "FED004")


def test_fed004_catalogue_check_skipped_outside_repro(tmp_path):
    src = '''
        def f(tel):
            tel.inc("synthetic", 1)
    '''
    assert not only(lint(tmp_path, src, "tests/fx.py"), "FED004")


def test_fed004_handle_assigned_from_tel(tmp_path):
    src = '''
        from repro import obs

        def f(n):
            t = obs.TEL
            t.inc(f"x_{n}", 1)
    '''
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert len(unwaived(fs, "FED004")) == 1


# ---------------------------------------------------------------------------
# FED005 — recompile hazard
# ---------------------------------------------------------------------------

def test_fed005_jit_in_per_call_body(tmp_path):
    src = """
        import jax

        def step(fn, x):
            f = jax.jit(fn)
            return f(x)
    """
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert len(unwaived(fs, "FED005")) == 1
    assert "step" in fs[0].message


def test_fed005_lru_cache_is_cache_evidence(tmp_path):
    src = """
        import functools
        import jax

        @functools.lru_cache(maxsize=None)
        def build(n):
            return jax.jit(make(n))
    """
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"),
                    "FED005")


def test_fed005_init_and_module_scope_ok(tmp_path):
    src = """
        import jax

        STEP = jax.jit(make())

        class Store:
            def __init__(self):
                self._prog = jax.jit(make())
    """
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"),
                    "FED005")


def test_fed005_module_level_loop_flagged(tmp_path):
    src = """
        import jax

        for n in (1, 2, 4):
            PROGS.append(jax.jit(make(n)))
    """
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert len(unwaived(fs, "FED005")) == 1
    assert "loop" in fs[0].message


def test_fed005_dict_cache_is_cache_evidence(tmp_path):
    src = """
        import jax

        def get(self, key):
            if key not in self._progs:
                self._progs[key] = jax.jit(make(key))
            return self._progs[key]
    """
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"),
                    "FED005")


def test_fed005_not_applied_in_launch(tmp_path):
    src = """
        import jax

        def step(fn, x):
            return jax.jit(fn)(x)
    """
    assert not only(lint(tmp_path, src, "src/repro/launch/fx.py"),
                    "FED005")


# ---------------------------------------------------------------------------
# FED006 — nondeterminism sources
# ---------------------------------------------------------------------------

# the PR 5 regression: builtin hash(str) is PYTHONHASHSEED-salted, so
# the per-client data salt differed across processes
FED006_HASH = """
    def client_salt(name):
        return hash(name) % 1000
"""


def test_fed006_builtin_hash_regression(tmp_path):
    fs = lint(tmp_path, FED006_HASH, "src/repro/data/fx.py")
    assert len(unwaived(fs, "FED006")) == 1
    assert "PYTHONHASHSEED" in fs[0].message


def test_fed006_crc32_salt_ok(tmp_path):
    src = """
        import zlib

        def client_salt(name):
            return zlib.crc32(name.encode()) % 1000
    """
    assert not only(lint(tmp_path, src, "src/repro/data/fx.py"),
                    "FED006")


def test_fed006_numpy_default_rng(tmp_path):
    src = """
        import numpy as np

        def noise(n):
            return np.random.rand(n)
    """
    fs = lint(tmp_path, src, "src/repro/data/fx.py")
    assert len(unwaived(fs, "FED006")) == 1
    assert "default_rng" in fs[0].message


def test_fed006_explicit_rng_ok(tmp_path):
    src = """
        import numpy as np

        def noise(n, seed):
            return np.random.default_rng(seed).random(n)
    """
    assert not only(lint(tmp_path, src, "src/repro/data/fx.py"),
                    "FED006")


def test_fed006_stdlib_random(tmp_path):
    src = """
        import random

        def pick(xs):
            return random.choice(xs)
    """
    assert only(lint(tmp_path, src, "src/repro/fl/fx.py"), "FED006")


def test_fed006_time_time_scoping(tmp_path):
    src = """
        import time

        def now():
            return time.time()
    """
    assert only(lint(tmp_path, src, "src/repro/core/fx.py"), "FED006")
    assert not only(lint(tmp_path, src, "benchmarks/fx.py"), "FED006")
    assert not only(lint(tmp_path, src, "src/repro/launch/fx.py"),
                    "FED006")


def test_fed006_datetime_scoping(tmp_path):
    src = """
        import datetime

        def stamp():
            return datetime.datetime.now()
    """
    assert only(lint(tmp_path, src, "src/repro/core/fx.py"), "FED006")
    assert not only(lint(tmp_path, src, "benchmarks/fx.py"), "FED006")


def test_fed006_waived(tmp_path):
    src = FED006_HASH.replace(
        "return hash(name) % 1000",
        "return hash(name) % 1000  "
        + waive("FED006", "per-process scratch key, never persisted"))
    fs = lint(tmp_path, src, "src/repro/data/fx.py")
    assert not unwaived(fs, "FED006")


# ---------------------------------------------------------------------------
# FED007 — broad exception handlers
# ---------------------------------------------------------------------------

def test_fed007_broad_and_bare(tmp_path):
    src = """
        def f():
            try:
                g()
            except Exception:
                pass
            try:
                g()
            except:
                pass
            try:
                g()
            except (ValueError, BaseException):
                pass
    """
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert len(unwaived(fs, "FED007")) == 3


def test_fed007_narrow_handler_ok(tmp_path):
    src = """
        def f():
            try:
                g()
            except (ValueError, KeyError):
                pass
    """
    assert not only(lint(tmp_path, src, "src/repro/core/fx.py"),
                    "FED007")


def test_fed007_waived(tmp_path):
    src = """
        def f():
            try:
                g()
            except Exception:  {w}
                pass
    """.format(w=waive("FED007", "sweep harness records and continues"))
    fs = lint(tmp_path, src, "src/repro/core/fx.py")
    assert not unwaived(fs, "FED007")
    assert only(fs, "FED007")[0].waived


# ---------------------------------------------------------------------------
# CLI: exit codes, --select, --json schema
# ---------------------------------------------------------------------------

def test_cli_clean_exits_zero(tmp_path, capsys):
    p = tmp_path / "clean.py"
    p.write_text("x = 1\n")
    assert fedlint_main([str(p)]) == 0
    assert "0 unwaived" in capsys.readouterr().out


def test_cli_unwaived_exits_one(tmp_path, capsys):
    p = tmp_path / "dirty.py"
    p.write_text("def f(name):\n    return hash(name)\n")
    assert fedlint_main([str(p)]) == 1
    assert "FED006" in capsys.readouterr().out


def test_cli_select_restricts_rules(tmp_path, capsys):
    p = tmp_path / "dirty.py"
    p.write_text("def f(name):\n    return hash(name)\n")
    assert fedlint_main([str(p), "--select", "FED007"]) == 0
    capsys.readouterr()


def test_cli_unknown_code_exits_two(tmp_path, capsys):
    p = tmp_path / "clean.py"
    p.write_text("x = 1\n")
    assert fedlint_main([str(p), "--select", "NOPE"]) == 2
    assert "unknown rule code" in capsys.readouterr().err


def test_cli_missing_path_exits_two(tmp_path, capsys):
    assert fedlint_main([str(tmp_path / "nope")]) == 2
    capsys.readouterr()


def test_cli_list_rules(capsys):
    assert fedlint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in sorted(ALL_CODES):
        assert code in out


def test_cli_json_report_schema(tmp_path, capsys):
    p = tmp_path / "dirty.py"
    p.write_text("def f(name):\n    return hash(name)\n")
    out = tmp_path / "report.json"
    rc = fedlint_main([str(p), "--json", str(out)])
    capsys.readouterr()
    assert rc == 1
    doc = json.loads(out.read_text())
    assert doc["fedlint"] == 1
    assert doc["meta_rule"] == META_RULE
    assert set(doc["rules"]) == ALL_CODES
    assert doc["paths"] == [str(p)]
    s = doc["summary"]
    assert set(s) == {"files", "total", "waived", "unwaived", "by_rule"}
    assert s["files"] == 1
    assert s["total"] == s["waived"] + s["unwaived"]
    assert s["unwaived"] >= 1
    for f in doc["findings"]:
        assert set(f) == {"file", "line", "col", "rule", "message",
                          "waived", "reason"}
    assert sum(s["by_rule"].values()) == s["total"]


# ---------------------------------------------------------------------------
# self-check: the shipped tree lints clean (the CI gate, run in-process)
# ---------------------------------------------------------------------------

def test_fedlint_self_check(monkeypatch, capsys):
    import pathlib
    repo = pathlib.Path(__file__).resolve().parents[1]
    monkeypatch.chdir(repo)
    rc = fedlint_main(["src", "tests", "benchmarks"])
    out = capsys.readouterr().out
    assert rc == 0, "fedlint found unwaived findings:\n" + out


# ---------------------------------------------------------------------------
# catalogue: kinds are disjoint, and a recorded run stays inside it
# ---------------------------------------------------------------------------

def test_catalogue_kinds_nearly_disjoint():
    # spans time things, metrics count things: a name may appear in
    # both namespaces (residency.write_behind is timed AND counts the
    # demoted rows), but the three metric kinds must never collide —
    # tel.summary() would silently shadow one with the other.
    metric_kinds = [catalogue.COUNTERS, catalogue.GAUGES, catalogue.HISTS]
    for i, a in enumerate(metric_kinds):
        for b in metric_kinds[i + 1:]:
            assert not (a & b)
    span_metric = catalogue.SPANS & (catalogue.COUNTERS
                                     | catalogue.GAUGES | catalogue.HISTS)
    assert span_metric <= {"residency.write_behind"}
    for name in catalogue.ALL:
        assert catalogue.kind_of(name) != "unknown"
    assert catalogue.kind_of("fl.response_s{tier=3}") == "hist"
    assert catalogue.kind_of("jax.cache.hits") == "counter"
    assert catalogue.kind_of("no.such.stream") == "unknown"


def test_recorded_flstats_names_are_catalogued():
    with obs.tracing() as tel:
        flstats.record_tiering([[0, 1], [2]], thresholds=[4.0, 8.0])
        flstats.record_selection([(0, 0), (2, 1)], population=3)
        flstats.record_response(1, 3.0, 4.0, timed_out=False)
        flstats.record_straggler("dropped", tier=1)
        flstats.record_staleness([0, 2], [0, 1])
        flstats.record_uplink(1024, tier=0)
        flstats.record_client_updates([0, 2])
    recorded = [("counter", n) for n in tel.counters] + \
               [("gauge", n) for n in tel.gauges] + \
               [("hist", n) for n in tel.hists]
    known = {"counter": catalogue.COUNTERS, "gauge": catalogue.GAUGES,
             "hist": catalogue.HISTS}
    assert recorded
    for kind, name in recorded:
        base, _labels = flstats.parse_label(name)
        ok = base in known[kind] or (
            kind == "counter"
            and base.startswith(catalogue.COUNTER_PREFIXES))
        assert ok, (kind, name)
