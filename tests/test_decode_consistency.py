"""Decode-vs-forward equivalence: sequentially decoding the prompt through
the KV/SSM caches must reproduce the full-sequence forward logits, for
every decodable family (the property that validates serve_step)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models import decode_step, forward, init_decode_state, init_model

pytestmark = pytest.mark.slow  # full-model decode loops, ~10 s each

B, S = 2, 16
KEY = jax.random.PRNGKey(1)


def _roundtrip(cfg, window=-1):
    params = init_model(cfg, KEY, dtype=jnp.float32)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = forward(cfg, params, {"tokens": toks}, window=window)
    state = init_decode_state(cfg, B, S, dtype=jnp.float32, window=window)
    outs = []
    for t in range(S):
        lg, state = decode_step(cfg, params, state, toks[:, t:t + 1],
                                window=window)
        outs.append(lg[:, 0])
    return jnp.stack(outs, 1), full


@pytest.mark.parametrize("arch", ["granite-20b", "llama3.2-1b",
                                  "phi4-mini-3.8b", "nemotron-4-340b",
                                  "chameleon-34b"])
def test_dense_families(arch):
    cfg = get_arch(arch).reduced()
    dec, full = _roundtrip(cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_moe_high_capacity():
    cfg = dataclasses.replace(get_arch("mixtral-8x7b").reduced(),
                              moe_capacity_factor=8.0, sliding_window=0)
    dec, full = _roundtrip(cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_moe_dense_residual_arctic():
    cfg = dataclasses.replace(get_arch("arctic-480b").reduced(),
                              moe_capacity_factor=8.0)
    dec, full = _roundtrip(cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_hybrid_hymba():
    cfg = get_arch("hymba-1.5b").reduced()
    dec, full = _roundtrip(cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_ssm_xlstm():
    cfg = get_arch("xlstm-350m").reduced()
    dec, full = _roundtrip(cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_variant():
    """Dense arch under the long_500k SWA override must agree with the
    windowed forward."""
    cfg = get_arch("llama3.2-1b").reduced()
    dec, full = _roundtrip(cfg, window=8)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
