"""Weighted aggregation: jnp path == kernel path == manual; properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import staleness_merge, weighted_average


def _params(seed, shapes=((4, 3), (7,), (2, 2, 2))):
    rng = np.random.default_rng(seed)
    return {f"p{i}": jnp.asarray(rng.normal(size=s).astype(np.float32))
            for i, s in enumerate(shapes)}


def test_weighted_average_matches_manual():
    ps = [_params(i) for i in range(3)]
    sizes = [10.0, 20.0, 30.0]
    out = weighted_average(ps, sizes)
    w = np.asarray(sizes) / np.sum(sizes)
    for k in ps[0]:
        manual = sum(wi * np.asarray(p[k]) for wi, p in zip(w, ps))
        np.testing.assert_allclose(np.asarray(out[k]), manual, rtol=1e-5, atol=1e-6)


def test_kernel_path_matches_jnp_path():
    ps = [_params(i) for i in range(4)]
    sizes = [1.0, 2.0, 3.0, 4.0]
    a = weighted_average(ps, sizes, use_kernel=False)
    b = weighted_average(ps, sizes, use_kernel=True)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-6)


@given(st.integers(2, 6), st.lists(st.floats(0.1, 100), min_size=2,
                                   max_size=6))
@settings(max_examples=30, deadline=None)
def test_aggregate_is_convex_combination(n, sizes):
    n = min(n, len(sizes))
    sizes = sizes[:n]
    ps = [_params(i, shapes=((3, 2),)) for i in range(n)]
    out = np.asarray(weighted_average(ps, sizes)["p0"])
    stack = np.stack([np.asarray(p["p0"]) for p in ps])
    assert (out <= stack.max(0) + 1e-5).all()
    assert (out >= stack.min(0) - 1e-5).all()


def test_staleness_merge_interpolates():
    a, b = _params(0), _params(1)
    mid = staleness_merge(a, b, 0.5)
    for k in a:
        np.testing.assert_allclose(
            np.asarray(mid[k]),
            0.5 * np.asarray(a[k]) + 0.5 * np.asarray(b[k]), rtol=1e-6)
    same = staleness_merge(a, b, 0.0)
    for k in a:
        np.testing.assert_allclose(np.asarray(same[k]), np.asarray(a[k]))


def test_empty_update_list_raises():
    with pytest.raises(ValueError):
        weighted_average([], [])
