"""Weighted aggregation: jnp path == kernel path == manual; properties.

Former hypothesis properties are seeded numpy parameter sweeps so the
suite collects without the optional dependency.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (staleness_merge, weighted_average,
                                    weighted_average_stacked)


def _params(seed, shapes=((4, 3), (7,), (2, 2, 2))):
    rng = np.random.default_rng(seed)
    return {f"p{i}": jnp.asarray(rng.normal(size=s).astype(np.float32))
            for i, s in enumerate(shapes)}


def test_weighted_average_matches_manual():
    ps = [_params(i) for i in range(3)]
    sizes = [10.0, 20.0, 30.0]
    out = weighted_average(ps, sizes)
    w = np.asarray(sizes) / np.sum(sizes)
    for k in ps[0]:
        manual = sum(wi * np.asarray(p[k]) for wi, p in zip(w, ps))
        np.testing.assert_allclose(np.asarray(out[k]), manual, rtol=1e-5, atol=1e-6)


def test_kernel_path_matches_jnp_path():
    ps = [_params(i) for i in range(4)]
    sizes = [1.0, 2.0, 3.0, 4.0]
    a = weighted_average(ps, sizes, use_kernel=False)
    b = weighted_average(ps, sizes, use_kernel=True)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("seed", range(10))
def test_aggregate_is_convex_combination(seed):
    # seeded sweep replacing the former hypothesis property
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 7))
    sizes = rng.uniform(0.1, 100.0, size=n).tolist()
    ps = [_params(seed * 100 + i, shapes=((3, 2),)) for i in range(n)]
    out = np.asarray(weighted_average(ps, sizes)["p0"])
    stack = np.stack([np.asarray(p["p0"]) for p in ps])
    assert (out <= stack.max(0) + 1e-5).all()
    assert (out >= stack.min(0) - 1e-5).all()


def test_staleness_merge_interpolates():
    a, b = _params(0), _params(1)
    mid = staleness_merge(a, b, 0.5)
    for k in a:
        np.testing.assert_allclose(
            np.asarray(mid[k]),
            0.5 * np.asarray(a[k]) + 0.5 * np.asarray(b[k]), rtol=1e-6)
    same = staleness_merge(a, b, 0.0)
    for k in a:
        np.testing.assert_allclose(np.asarray(same[k]), np.asarray(a[k]))


def test_empty_update_list_raises():
    with pytest.raises(ValueError):
        weighted_average([], [])


# ---------------------------------------------------------------------------
# stacked (engine) API
# ---------------------------------------------------------------------------

def _stacked(ps):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_stacked_matches_list_api(use_kernel):
    ps = [_params(i) for i in range(5)]
    sizes = [3.0, 1.0, 4.0, 1.0, 5.0]
    a = weighted_average(ps, sizes)
    b = weighted_average_stacked(_stacked(ps), jnp.asarray(sizes),
                                 use_kernel=use_kernel)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_stacked_zero_weight_masks_straggler(use_kernel):
    """A zero-weight row contributes nothing, even when it is non-finite
    garbage (an untrained straggler slot)."""
    ps = [_params(i) for i in range(4)]
    poisoned = jax.tree_util.tree_map(lambda x: x * np.nan, ps[2])
    stacked = _stacked([ps[0], ps[1], poisoned, ps[3]])
    w = jnp.asarray([1.0, 2.0, 0.0, 3.0])
    out = weighted_average_stacked(stacked, w, use_kernel=use_kernel)
    ref = weighted_average([ps[0], ps[1], ps[3]], [1.0, 2.0, 3.0])
    for k in ref:
        assert bool(jnp.all(jnp.isfinite(out[k])))
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_stacked_all_zero_weights_gives_zeros(use_kernel):
    ps = [_params(i) for i in range(3)]
    out = weighted_average_stacked(_stacked(ps), jnp.zeros(3),
                                   use_kernel=use_kernel)
    for k in out:
        np.testing.assert_allclose(np.asarray(out[k]), 0.0, atol=1e-6)


def test_stacked_mixed_dtype_pytree_kernel_parity():
    """bf16 + f32 leaves in one pytree: the flattened kernel pass casts
    per-leaf and restores each leaf's dtype."""
    rng = np.random.default_rng(0)
    ps = []
    for i in range(3):
        ps.append({
            "a": jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(9,)).astype(np.float32)
                             ).astype(jnp.bfloat16),
        })
    sizes = jnp.asarray([1.0, 2.0, 3.0])
    out_k = weighted_average_stacked(_stacked(ps), sizes, use_kernel=True)
    out_j = weighted_average_stacked(_stacked(ps), sizes, use_kernel=False)
    assert out_k["a"].dtype == jnp.float32
    assert out_k["b"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out_k["a"]),
                               np.asarray(out_j["a"]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_k["b"], np.float32),
                               np.asarray(out_j["b"], np.float32),
                               rtol=2e-2, atol=2e-2)
