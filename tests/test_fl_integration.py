"""End-to-end FL integration: real training, all four methods, paper-
shaped claims in miniature (tiny datasets so CI stays fast)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.metrics import RunHistory
from repro.fl.network import WirelessNetwork


def _setup(mu=0.0, rounds=10, n_clients=10, seed=0, arch="cnn-mnist",
           scale=0.01, **kw):
    fl = FLConfig(n_clients=n_clients, n_tiers=5, tau=2, rounds=rounds,
                  mu=mu, primary_frac=0.7, seed=seed, lr=0.003, **kw)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    tr = build_fl_clients(arch, fl, scale=scale)
    return tr, net, fl


@pytest.mark.slow
def test_feddct_learns_on_cnn():
    tr, net, fl = _setup(rounds=15, scale=0.03)
    h = run_method("feddct", tr, net, fl, eval_every=5)
    assert h.accuracy[-1] > h.accuracy[0] + 0.05


@pytest.mark.slow
@pytest.mark.parametrize("extra", [(), ("--hot-rows", "2")],
                         ids=["dense-store", "tiered-residency"])
def test_fl_train_exactly_reproducible_across_processes(tmp_path, extra):
    """Regression for the cross-process nondeterminism observed at the
    PR 4 seed state: same ``fl_train.py`` flags in two FRESH processes
    (different PYTHONHASHSEED, the entropy source the bug rode on) must
    write byte-identical RunHistory JSON.  In-process A/B was always
    bitwise — only a new interpreter exposed the salted ``hash(name)``
    in the dataset seed.  The tiered-residency arm runs the same gate
    with a hot tier smaller than the cohort (capacity 2 < 4 clients),
    so eviction and host round-trips must also be hash-seed-proof."""
    repo = os.path.join(os.path.dirname(__file__), "..")
    outs = []
    for hashseed in ("1", "2"):
        out = str(tmp_path / f"hist_{hashseed}.json")
        env = dict(os.environ, PYTHONHASHSEED=hashseed,
                   PYTHONPATH="src" + os.pathsep
                   + os.environ.get("PYTHONPATH", ""))
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.fl_train",
             "--arch", "cnn-mnist", "--method", "fedbuff",
             "--rounds", "2", "--clients", "4", "--tau", "2",
             "--window", "2", "--seed", "0", "--out", out, *extra],
            env=env, cwd=repo, capture_output=True, text=True,
            timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append(out)
    with open(outs[0]) as f0, open(outs[1]) as f1:
        h0, h1 = json.load(f0), json.load(f1)
    assert h0 == h1


@pytest.mark.slow
def test_all_methods_produce_histories():
    tr, net, fl = _setup(rounds=3, scale=0.01)
    for m in ("feddct", "fedavg", "tifl", "fedasync"):
        h = run_method(m, tr, net, fl, eval_every=1)
        assert isinstance(h, RunHistory)
        assert len(h.accuracy) >= 1
        assert h.method == m


@pytest.mark.slow
def test_feddct_time_advantage_same_model_quality_path():
    """Same network realization, same rounds: FedDCT's clock < FedAvg's
    (paper Table 2 time column, miniature)."""
    tr, net, fl = _setup(mu=0.3, rounds=6, scale=0.01)
    t_dct = run_method("feddct", tr, net, fl).times[-1]
    tr2, net2, fl2 = _setup(mu=0.3, rounds=6, scale=0.01)
    t_avg = run_method("fedavg", tr2, net2, fl2).times[-1]
    assert t_dct < t_avg


@pytest.mark.slow
def test_lm_trainer_fl_roundtrip():
    """FedDCT over a reduced LLM architecture (deliverable-f integration)."""
    fl = FLConfig(n_clients=6, n_tiers=3, tau=2, rounds=3, mu=0.0,
                  primary_frac=0.7, seed=0, lr=1e-3)
    net = WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                          fl.mu, fl.failure_delay, fl.seed)
    tr = build_fl_clients("llama3.2-1b", fl)
    h = run_method("feddct", tr, net, fl)
    assert len(h.accuracy) == 3
    assert all(0.0 <= a <= 1.0 for a in h.accuracy)


def test_history_json_roundtrip(tmp_path):
    tr, net, fl = _setup(rounds=2, scale=0.01)
    h = run_method("feddct", tr, net, fl)
    p = str(tmp_path / "h.json")
    h.save(p)
    h2 = RunHistory.load(p)
    assert h2.accuracy == h.accuracy
    assert h2.times == h.times
    assert h2.meta == h.meta


def test_history_schema_version():
    """``to_json`` stamps the schema version at the TOP level (never in
    meta); ``from_json`` round-trips every field, accepts legacy v0
    dicts, ignores unknown keys, and rejects newer versions."""
    from repro.fl.metrics import SCHEMA_VERSION
    h = RunHistory(method="x", arch="y", meta={"k": 1})
    h.record(time=1.0, rnd=1, acc=0.5, tier=2, n_selected=3,
             n_stragglers=1)
    d = h.to_json()
    assert d["schema_version"] == SCHEMA_VERSION
    assert "schema_version" not in d["meta"]
    h2 = RunHistory.from_json(d)
    assert h2 == h
    # legacy v0: a bare __dict__ dump with no schema_version key
    legacy = {k: v for k, v in d.items() if k != "schema_version"}
    assert RunHistory.from_json(legacy) == h
    # forward drift: unknown keys are dropped, not fatal
    assert RunHistory.from_json({**d, "novel_field": 42}) == h
    with pytest.raises(ValueError, match="newer"):
        RunHistory.from_json({**d, "schema_version": SCHEMA_VERSION + 1})


def test_time_to_accuracy_helper():
    h = RunHistory(method="x", arch="y")
    h.record(time=1.0, rnd=1, acc=0.2)
    h.record(time=2.0, rnd=2, acc=0.6)
    assert h.time_to_accuracy(0.5) == 2.0
    assert h.time_to_accuracy(0.9) is None
    assert h.best_accuracy(smooth=1) == 0.6


def test_fl_server_state_checkpoint_roundtrip(tmp_path):
    """Global model params survive a save/restore mid-run."""
    import jax
    import numpy as np
    from repro.checkpoint import save_checkpoint, load_checkpoint
    tr, net, fl = _setup(rounds=2, scale=0.01)
    h = run_method("feddct", tr, net, fl)
    params = tr.init_params(0)
    save_checkpoint(str(tmp_path), 2, params)
    restored = load_checkpoint(str(tmp_path), 2, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
