"""MoE dispatch: exactness vs dense oracle, capacity drops, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import capacity_for, init_moe, moe_ffn

KEY = jax.random.PRNGKey(0)


def _naive(p, x, top_k, activation="swiglu", dense_residual=False):
    e = p["router"].shape[1]
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    y = jnp.zeros_like(x)
    for ex in range(e):
        if activation == "swiglu":
            h = jax.nn.silu(x @ p["w_gate"][ex]) * (x @ p["w_up"][ex])
        else:
            h = jax.nn.gelu(x @ p["w_up"][ex])
        fe = h @ p["w_down"][ex]
        w = ((ei == ex) * gv).sum(-1)
        y = y + fe * w[..., None]
    if dense_residual:
        from repro.models.layers import mlp
        y = y + mlp(p["dense_mlp"], x, activation)
    return y


@pytest.mark.parametrize("e,k,g", [
    (4, 2, 8),
    pytest.param(8, 2, 16, marks=pytest.mark.slow),
    pytest.param(4, 1, 8, marks=pytest.mark.slow),
])
def test_moe_matches_dense_oracle_no_drops(e, k, g):
    d, ff = 16, 32
    p = init_moe(KEY, d, ff, e, "swiglu")
    x = jax.random.normal(KEY, (2, g, d), jnp.float32)
    y, aux = moe_ffn(p, x, top_k=k, activation="swiglu",
                     capacity_factor=float(e))   # no drops possible
    ref = _naive(p, x, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
    assert 0.5 <= float(aux) <= float(e)


def test_moe_dense_residual():
    d, ff, e, k = 16, 32, 4, 2
    p = init_moe(KEY, d, ff, e, "swiglu", dense_residual=True, dense_ff=24)
    x = jax.random.normal(KEY, (1, 8, d), jnp.float32)
    y, _ = moe_ffn(p, x, top_k=k, activation="swiglu", capacity_factor=4.0,
                   dense_residual=True)
    ref = _naive(p, x, k, dense_residual=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_capacity_drops_reduce_output_norm():
    """With capacity 1 slot/expert, overflow tokens pass through as zero
    MoE output — norms shrink vs no-drop routing."""
    d, ff, e, k = 8, 16, 2, 1
    p = init_moe(KEY, d, ff, e, "gelu")
    x = jax.random.normal(KEY, (1, 16, d), jnp.float32)
    y_full, _ = moe_ffn(p, x, top_k=k, activation="gelu",
                        capacity_factor=float(e * 16))
    y_tight, _ = moe_ffn(p, x, top_k=k, activation="gelu",
                         capacity_factor=0.1)
    n_full = float(jnp.linalg.norm(y_full))
    n_tight = float(jnp.linalg.norm(y_tight))
    assert n_tight < n_full


def test_capacity_for_bounds():
    assert capacity_for(16, 2, 4, 1.25) == 10
    assert capacity_for(1, 2, 8, 1.25) == 1
    assert capacity_for(100, 2, 4, 100.0) == 200   # clamped to S*k


@pytest.mark.parametrize("e_log,k,g", [
    (2, 2, 7), (3, 1, 16),
    pytest.param(2, 1, 4, marks=pytest.mark.slow),
    pytest.param(3, 2, 9, marks=pytest.mark.slow),
    pytest.param(4, 2, 32, marks=pytest.mark.slow),
    pytest.param(5, 1, 12, marks=pytest.mark.slow),
    pytest.param(4, 1, 21, marks=pytest.mark.slow),
    pytest.param(5, 2, 5, marks=pytest.mark.slow),
])
def test_moe_output_finite_any_shape(e_log, k, g):
    e = 2 ** e_log
    k = min(k, e)
    d, ff = 8, 16
    p = init_moe(KEY, d, ff, e, "swiglu")
    x = jax.random.normal(KEY, (1, g, d), jnp.float32)
    y, aux = moe_ffn(p, x, top_k=k, activation="swiglu",
                     capacity_factor=1.25)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.99  # load-balance loss lower bound is ~1


def test_decode_single_token_group_fallback():
    d, ff, e, k = 8, 16, 4, 2
    p = init_moe(KEY, d, ff, e, "swiglu")
    x = jax.random.normal(KEY, (8, 1, d), jnp.float32)   # decode layout
    y, _ = moe_ffn(p, x, top_k=k, activation="swiglu", capacity_factor=2.0)
    ref = _naive(p, x, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
