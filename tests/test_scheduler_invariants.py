"""Regression tests for scheduler invariants the engine refactor must
preserve: clock monotonicity, straggler exclusion, eval-lane rejoin,
and tiering partition structure."""

import numpy as np
import pytest

from repro.config.base import FLConfig
from repro.core.scheduler import run_feddct
from repro.core.tiering import tiering
from repro.fl.network import WirelessNetwork


class TraceTrainer:
    """Instant trainer that records exactly which clients trained in
    which round (to prove stragglers never contribute)."""

    class cfg:
        arch_id = "trace"

    def __init__(self):
        self.n_evals = 0
        self.trained_by_round = {}
        self._rnd = 0

    def init_params(self, seed=0):
        return {"w": np.zeros(2, np.float32)}

    def local_train(self, params, client_id, rnd_seed):
        self.trained_by_round.setdefault(rnd_seed, []).append(client_id)
        return {"w": params["w"] + 1.0}, 10

    def local_train_batch(self, params, client_ids, rnd_seed):
        import jax.numpy as jnp
        self.trained_by_round.setdefault(rnd_seed, []).extend(client_ids)
        stacked = {"w": jnp.stack([jnp.asarray(params["w"]) + 1.0
                                   for _ in client_ids])}
        return stacked, np.full(len(client_ids), 10.0, np.float32)

    def evaluate(self, params, **kw):
        self.n_evals += 1
        return min(0.01 * self.n_evals, 0.99)


def _fl(**kw):
    base = dict(n_clients=20, n_tiers=4, tau=2, rounds=12, kappa=1,
                omega=30.0, beta=1.2, seed=3)
    base.update(kw)
    return FLConfig(**base)


def _net(fl, mu=0.0):
    return WirelessNetwork(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                           mu, fl.failure_delay, fl.seed)


@pytest.mark.parametrize("mu,engine", [(0.0, "batched"), (0.6, "batched"),
                                       (0.6, "looped")])
def test_virtual_clock_monotone_nondecreasing(mu, engine):
    fl = _fl()
    hist = run_feddct(TraceTrainer(), _net(fl, mu=mu), fl, engine=engine)
    assert all(b >= a for a, b in zip(hist.times, hist.times[1:]))
    assert hist.times[0] >= 0.0


@pytest.mark.parametrize("engine", ["batched", "looped"])
def test_stragglers_updates_never_aggregated(engine):
    """Replay the scheduler's own straggler rule: any client whose delay
    >= its tier D_max in a round must not appear in that round's
    training set."""
    fl = _fl(rounds=10)
    tr = TraceTrainer()
    net = _net(fl, mu=0.7)
    hist = run_feddct(tr, net, fl, engine=engine)
    assert sum(hist.n_stragglers) > 0          # scenario has stragglers
    for rnd, trained in tr.trained_by_round.items():
        for c in trained:
            # a trained client's delay was strictly under omega (D_max
            # is capped at omega, Eq. 7), so this is a necessary
            # condition of the invariant
            assert net.delay(c, rnd) < fl.omega
    # no duplicates within a round
    for trained in tr.trained_by_round.values():
        assert len(trained) == len(set(trained))


class OneStraggleNet(WirelessNetwork):
    """Deterministic scenario: one fast client times out (only on its
    actual training attempt) during a window of rounds."""

    def __init__(self, *a, straggle_client=0, straggle_rounds=(), **k):
        super().__init__(*a, **k)
        self.sc = straggle_client
        self.srs = set(straggle_rounds)

    def delay(self, client, rnd, attempt=0):
        if client == self.sc and rnd in self.srs and attempt == 0:
            return 1e6
        return super().delay(client, rnd, attempt)


def test_eval_lane_rejoins_with_refreshed_average():
    """A straggler enters the re-evaluation lane and, once its virtual
    evaluation time has passed, rejoins with a refreshed average time —
    it trains again instead of being dropped for good (the FedDCT vs
    TiFL distinction)."""
    fl = _fl(rounds=20)
    tr = TraceTrainer()
    # client 0 is in the fastest group (tier 1) and times out whenever
    # it is picked during rounds 2-6
    net = OneStraggleNet(fl.n_clients, fl.tier_delay_means, fl.delay_std,
                         0.0, fl.failure_delay, fl.seed,
                         straggle_client=0, straggle_rounds=range(2, 7))
    hist = run_feddct(tr, net, fl, engine="batched")
    assert sum(hist.n_stragglers) >= 1        # the timeout actually hit
    rounds_trained_0 = sorted(r for r, cs in tr.trained_by_round.items()
                              if 0 in cs)
    # never trained during the straggle window...
    assert not any(2 <= r < 7 for r in rounds_trained_0)
    # ...but rejoined afterwards (at[0] was refreshed, not deleted)
    assert any(r >= 7 for r in rounds_trained_0)


def test_tiering_is_partition_with_tier1_fastest():
    rng = np.random.default_rng(0)
    at = {int(c): float(t) for c, t in
          zip(range(23), rng.uniform(0.5, 40.0, 23))}
    tiers = tiering(at, m=5)
    flat = [c for t in tiers for c in t]
    assert sorted(flat) == sorted(at)                     # partition
    assert all(len(t) == 5 for t in tiers[:-1])
    for a, b in zip(tiers[:-1], tiers[1:]):               # tier-1 fastest
        assert max(at[c] for c in a) <= min(at[c] for c in b)


def test_round_time_capped_by_omega_under_failures():
    fl = _fl(rounds=8)
    hist = run_feddct(TraceTrainer(), _net(fl, mu=0.9), fl)
    deltas = np.diff([0] + hist.times)
    # first delta includes the parallel profiling setup
    assert all(d <= fl.omega + 1e-6 for d in deltas[1:])
