"""Per-arch smoke tests (deliverable f): REDUCED variant of each assigned
family — one forward + one train step + one decode step on CPU, asserting
output shapes and no NaNs."""


import jax
import jax.numpy as jnp
import pytest

from repro.config import get_arch
from repro.config.base import TrainConfig
from repro.launch.steps import make_train_step
from repro.models import (cnn_forward, decode_step, forward, init_cnn,
                          init_decode_state, init_model)

pytestmark = pytest.mark.slow  # one train step per zoo arch, ~5-10 s each

ASSIGNED = ["granite-20b", "nemotron-4-340b", "phi4-mini-3.8b",
            "llama3.2-1b", "mixtral-8x7b", "hubert-xlarge", "hymba-1.5b",
            "arctic-480b", "xlstm-350m", "chameleon-34b"]

B, S = 2, 32


def _batch(cfg, key):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_no_nan(arch, key):
    cfg = get_arch(arch).reduced()
    params = init_model(cfg, key, dtype=jnp.float32)
    logits, aux = forward(cfg, params, _batch(cfg, key))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch, key):
    cfg = get_arch(arch).reduced()
    tcfg = TrainConfig(dtype="float32", remat=False, attn_chunk_q=32,
                       attn_chunk_kv=32, lr=1e-3)
    params = init_model(cfg, key, dtype=jnp.float32)
    step, opt = make_train_step(cfg, tcfg)
    opt_state = opt.init(params)
    batch = _batch(cfg, key)
    p2, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if a != "hubert-xlarge"])
def test_one_decode_step(arch, key):
    cfg = get_arch(arch).reduced()
    params = init_model(cfg, key, dtype=jnp.float32)
    state = init_decode_state(cfg, B, 64, dtype=jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, state2 = decode_step(cfg, params, state, tok)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(state2["pos"]) == 1


def test_encoder_only_has_no_decode(key):
    cfg = get_arch("hubert-xlarge").reduced()
    params = init_model(cfg, key, dtype=jnp.float32)
    state_err = None
    with pytest.raises(ValueError):
        decode_step(cfg, params, {"layers": None, "pos": jnp.zeros((), jnp.int32)},
                    jnp.ones((B, 1), jnp.int32))


@pytest.mark.parametrize("arch", ["cnn-mnist", "cnn-fmnist",
                                  "resnet8-cifar10"])
def test_cnn_smoke(arch, key):
    cfg = get_arch(arch)
    params = init_cnn(cfg, key)
    h, w, c = cfg.input_hw
    x = jax.random.normal(key, (4, h, w, c))
    logits = cnn_forward(cfg, params, x)
    assert logits.shape == (4, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_loss_decreases_over_steps(key):
    """End-to-end learning sanity on the smallest arch."""
    cfg = get_arch("llama3.2-1b").reduced()
    tcfg = TrainConfig(dtype="float32", remat=False, attn_chunk_q=32,
                       attn_chunk_kv=32, lr=3e-3)
    params = init_model(cfg, key, dtype=jnp.float32)
    step, opt = make_train_step(cfg, tcfg)
    opt_state = opt.init(params)
    jstep = jax.jit(step)
    batch = _batch(cfg, key)    # same batch: loss must fall
    losses = []
    for _ in range(8):
        params, opt_state, m = jstep(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
