"""End-to-end driver (deliverable b): federated training of the paper's
CNN on synthetic MNIST for a few hundred rounds, all four methods,
checkpointing + JSON histories.

    PYTHONPATH=src python examples/feddct_mnist.py --rounds 200 \
        --clients 50 --mu 0.1 --scale 0.1 --out runs/mnist

Paper setting: 50 clients, M=5 tiers, tau=5, beta=1.2, kappa=1, Omega=30s,
lr=0.001, batch 10, local epoch 1, #=0.7.
"""

import argparse
import os

from repro.checkpoint import save_checkpoint
from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.network import WirelessNetwork


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--mu", type=float, default=0.1)
    ap.add_argument("--primary-frac", type=float, default=0.7)
    ap.add_argument("--scale", type=float, default=0.1,
                    help="dataset scale (1.0 = full 60k MNIST)")
    ap.add_argument("--methods", default="feddct,fedavg,tifl,fedasync")
    ap.add_argument("--dataset", default="cnn-mnist",
                    choices=["cnn-mnist", "cnn-fmnist", "resnet8-cifar10"])
    ap.add_argument("--out", default="runs/mnist")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    fl = FLConfig(n_clients=args.clients, n_tiers=5, tau=5,
                  rounds=args.rounds, mu=args.mu,
                  primary_frac=args.primary_frac, seed=args.seed,
                  lr=0.001, batch_size=10, local_epochs=1,
                  beta=1.2, kappa=1, omega=30.0)

    summary = []
    for method in args.methods.split(","):
        net = WirelessNetwork(fl.n_clients, fl.tier_delay_means,
                              fl.delay_std, fl.mu, fl.failure_delay, fl.seed)
        trainer = build_fl_clients(args.dataset, fl, scale=args.scale)
        hist = run_method(method, trainer, net, fl, verbose=True,
                          eval_every=5)
        hist.save(os.path.join(args.out, f"{method}.json"))
        summary.append((method, hist.best_accuracy(),
                        hist.times[-1]))
    print("\nmethod     best_acc   virtual_time")
    for m, acc, t in summary:
        print(f"{m:10s} {acc:8.4f}   {t:10.1f}s")


if __name__ == "__main__":
    main()
