"""FedDCT as a datacenter scheduler: the paper's algorithm coordinating
*LLM* clients (reduced configs of the assigned architectures), not CNNs.

Each "client" performs a real train step on its own token shard; the
wireless model supplies heterogeneous virtual step times.  This is the
DESIGN.md §2 embodiment where tiers = replica groups of a pod.

    PYTHONPATH=src python examples/multi_arch_fl.py
"""

from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.network import WirelessNetwork


def main():
    for arch in ("llama3.2-1b", "xlstm-350m", "hymba-1.5b"):
        fl = FLConfig(n_clients=8, n_tiers=4, tau=2, rounds=6, mu=0.2,
                      primary_frac=0.7, seed=0, lr=1e-3)
        net = WirelessNetwork(fl.n_clients, fl.tier_delay_means,
                              fl.delay_std, fl.mu, fl.failure_delay, fl.seed)
        trainer = build_fl_clients(arch, fl)       # reduced LM trainer
        hist = run_method("feddct", trainer, net, fl)
        print(f"{arch:14s} next-token acc {hist.accuracy[0]:.4f} -> "
              f"{hist.accuracy[-1]:.4f}  virtual {hist.times[-1]:.0f}s "
              f"tiers={hist.tier}")


if __name__ == "__main__":
    main()
