"""Quickstart: FedDCT vs FedAvg on synthetic MNIST in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's headline effect: with unreliable clients (mu=0.3),
FedDCT reaches the same accuracy in a fraction of FedAvg's virtual
wall-clock, because the dynamic tiering + per-tier timeouts stop
stragglers from stalling every round.
"""

from repro.config.base import FLConfig
from repro.core import run_method
from repro.fl.client import build_fl_clients
from repro.fl.network import WirelessNetwork


def main():
    fl = FLConfig(n_clients=20, n_tiers=5, tau=3, rounds=20, mu=0.3,
                  primary_frac=0.7, seed=0, lr=0.003)
    print(f"== FedDCT quickstart: {fl.n_clients} clients, mu={fl.mu}, "
          f"#={fl.primary_frac}, {fl.rounds} rounds ==")

    results = {}
    for method in ("feddct", "fedavg"):
        net = WirelessNetwork(fl.n_clients, fl.tier_delay_means,
                              fl.delay_std, fl.mu, fl.failure_delay, fl.seed)
        trainer = build_fl_clients("cnn-mnist", fl, scale=0.02)
        hist = run_method(method, trainer, net, fl, verbose=True,
                          eval_every=4)
        results[method] = hist

    print("\n== summary ==")
    for m, h in results.items():
        print(f"{m:8s} best_acc={h.best_accuracy(smooth=1):.4f} "
              f"virtual_time={h.times[-1]:8.1f}s")
    speedup = results["fedavg"].times[-1] / results["feddct"].times[-1]
    print(f"\nFedDCT finished the same {fl.rounds} rounds "
          f"{speedup:.1f}x faster in simulated wall-clock (paper Table 2 "
          f"reports 31-68% time reductions).")


if __name__ == "__main__":
    main()
