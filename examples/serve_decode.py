"""Batched-request serving example: prefill + KV-cache decode for any
decodable assigned architecture (reduced config on CPU).

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main()
